//! Simulated GPU cluster: topology + virtual-time network model.
//!
//! The paper's testbeds are 16 machines × 8 GPUs on 25 Gbps TCP or
//! 100 Gbps RDMA, with NVLink inside a machine. We reproduce the
//! *communication structure* exactly — every scheme really moves the
//! bytes it claims between in-process endpoints — and charge time with
//! the standard synchronous α–β model that the paper's own Appendix B
//! analysis uses:
//!
//! `stage_time = α + max_endpoint(max(bytes_sent, bytes_recv)) · 8 / B`
//!
//! Full-duplex NICs, receiver/sender bottleneck at the busiest endpoint —
//! which is precisely what makes imbalanced schemes slow (Lemma 4) and
//! balanced ones fast.
//!
//! Two placements of the GPUs are supported:
//!
//! - **Flat** ([`Network::new`]): endpoints are machines; GPUs inside a
//!   machine first reduce-scatter/all-gather dense shards over NVLink
//!   (§4.1) — [`Topology::intra_machine_time`] charges that phase — and
//!   the inter-machine schemes operate on per-machine tensors.
//! - **Two-level** ([`Network::with_topology`]): endpoints are ranks
//!   placed on nodes by a [`Topology`]; each synchronous stage is
//!   charged *per link class* — frames between co-located ranks ride
//!   the intra-node link, cross-node frames the fabric, and the stage
//!   costs the max of the two classes (they are physically parallel
//!   links). [`StageReport`] keeps the per-class split.

pub mod report;
pub mod topology;

pub use report::{
    ClassStage, ClassedJob, CommReport, StageReport, Timeline, TimelineEntry, TimelineJob,
};
pub use topology::{LinkClass, Topology, LINK_CLASSES};

/// Link presets matching the paper's two testbeds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LinkKind {
    /// 25 Gbps Ethernet, TCP/IP (testbed 1).
    Tcp25,
    /// 100 Gbps, RDMA (testbed 2).
    Rdma100,
    /// NVLink (V100-gen: ~150 GB/s per direction aggregate).
    NvLink,
    /// Custom bits/s + latency (ns).
    Custom(u64, u64),
}

impl LinkKind {
    /// Bandwidth in bits per second.
    pub fn bandwidth_bps(&self) -> f64 {
        match self {
            LinkKind::Tcp25 => 25e9,
            LinkKind::Rdma100 => 100e9,
            LinkKind::NvLink => 150e9 * 8.0,
            LinkKind::Custom(bps, _) => *bps as f64,
        }
    }

    /// Per-stage latency α in seconds (TCP pays kernel/stack overhead;
    /// RDMA and NVLink are in the microsecond regime).
    pub fn latency(&self) -> f64 {
        match self {
            LinkKind::Tcp25 => 50e-6,
            LinkKind::Rdma100 => 5e-6,
            LinkKind::NvLink => 2e-6,
            LinkKind::Custom(_, ns) => *ns as f64 * 1e-9,
        }
    }
}

/// The network under a synchronization: endpoint count + placement.
/// `link` is the inter-node (fabric) link — the historical single
/// global pair — and `topo` carries the full per-class placement the
/// transports charge time with (flat unless built via
/// [`with_topology`](Network::with_topology)).
#[derive(Clone, Debug)]
pub struct Network {
    pub link: LinkKind,
    pub endpoints: usize,
    pub topo: Topology,
}

impl Network {
    /// Flat network: every endpoint pair crosses `link`.
    pub fn new(endpoints: usize, link: LinkKind) -> Self {
        assert!(endpoints >= 1);
        Network {
            endpoints,
            link,
            topo: Topology::flat(endpoints, link),
        }
    }

    /// Two-level network: one endpoint per rank of `topo`, traffic
    /// charged per link class.
    pub fn with_topology(topo: Topology) -> Self {
        let endpoints = topo.endpoints();
        assert!(endpoints >= 1);
        Network {
            endpoints,
            link: topo.inter,
            topo,
        }
    }

    /// α–β time of one link class given its busiest endpoint's bytes
    /// (0 when the class carried nothing — an idle link charges no α).
    pub fn class_time(&self, class: LinkClass, busiest_bytes: u64) -> f64 {
        if busiest_bytes == 0 {
            return 0.0;
        }
        let link = self.topo.link_of(class);
        link.latency() + busiest_bytes as f64 * 8.0 / link.bandwidth_bps()
    }

    /// Time for one synchronous stage given per-endpoint sent/recv bytes,
    /// charged entirely to the inter link (the flat model's accounting;
    /// classed callers use [`class_time`](Network::class_time) per class
    /// and take the max).
    pub fn stage_time(&self, sent: &[u64], recv: &[u64]) -> f64 {
        assert_eq!(sent.len(), self.endpoints);
        assert_eq!(recv.len(), self.endpoints);
        let busiest = sent
            .iter()
            .zip(recv.iter())
            .map(|(&s, &r)| s.max(r))
            .max()
            .unwrap_or(0);
        if busiest == 0 {
            return 0.0;
        }
        self.link.latency() + busiest as f64 * 8.0 / self.link.bandwidth_bps()
    }

    /// Build a stage report from a per-(src,dst) byte matrix
    /// (`bytes[src][dst]`, diagonal ignored — local moves are free),
    /// classifying every pair against the topology.
    pub fn stage_from_matrix(&self, name: &str, bytes: &[Vec<u64>]) -> StageReport {
        assert_eq!(bytes.len(), self.endpoints);
        let n = self.endpoints;
        let mut sent = vec![0u64; n];
        let mut recv = vec![0u64; n];
        let mut class_sent = [vec![0u64; n], vec![0u64; n]];
        let mut class_recv = [vec![0u64; n], vec![0u64; n]];
        for (src, row) in bytes.iter().enumerate() {
            assert_eq!(row.len(), n);
            for (dst, &b) in row.iter().enumerate() {
                if src != dst {
                    sent[src] += b;
                    recv[dst] += b;
                    let c = self.topo.class_of(src, dst).idx();
                    class_sent[c][src] += b;
                    class_recv[c][dst] += b;
                }
            }
        }
        let classes = LINK_CLASSES.map(|class| {
            let c = class.idx();
            let busiest = class_sent[c]
                .iter()
                .zip(class_recv[c].iter())
                .map(|(&s, &r)| s.max(r))
                .max()
                .unwrap_or(0);
            ClassStage {
                bytes: class_sent[c].iter().sum(),
                busiest,
                time: self.class_time(class, busiest),
            }
        });
        let time = classes[0].time.max(classes[1].time);
        StageReport {
            name: name.to_string(),
            sent,
            recv,
            time,
            classes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_sane() {
        assert_eq!(LinkKind::Tcp25.bandwidth_bps(), 25e9);
        assert_eq!(LinkKind::Rdma100.bandwidth_bps(), 100e9);
        assert!(LinkKind::NvLink.bandwidth_bps() > LinkKind::Rdma100.bandwidth_bps());
        assert!(LinkKind::Tcp25.latency() > LinkKind::Rdma100.latency());
    }

    #[test]
    fn stage_time_bottleneck_endpoint() {
        let net = Network::new(3, LinkKind::Custom(8_000_000_000, 0)); // 1 GB/s
        // endpoint 1 receives 2 GB → 2 s
        let t = net.stage_time(&[0, 0, 0], &[0, 2_000_000_000, 0]);
        assert!((t - 2.0).abs() < 1e-9);
        // balanced: 3 endpoints each receive 1 GB → 1 s (3× better than
        // one endpoint receiving 3 GB — the Lemma 4 effect)
        let bal = net.stage_time(&[0, 0, 0], &[1_000_000_000; 3]);
        let imb = net.stage_time(&[0, 0, 0], &[3_000_000_000, 0, 0]);
        assert!((imb / bal - 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_stage_free() {
        let net = Network::new(2, LinkKind::Tcp25);
        assert_eq!(net.stage_time(&[0, 0], &[0, 0]), 0.0);
    }

    #[test]
    fn matrix_accounting() {
        let net = Network::new(3, LinkKind::Custom(8, 0)); // 1 B/s
        let m = vec![
            vec![0, 10, 20], // node 0 sends 30
            vec![5, 0, 0],
            vec![0, 0, 7], // diagonal ignored
        ];
        let st = net.stage_from_matrix("x", &m);
        assert_eq!(st.sent, vec![30, 5, 0]);
        assert_eq!(st.recv, vec![5, 10, 20]);
        assert!((st.time - 30.0).abs() < 1e-9);
        // flat: everything lands in the inter class
        assert_eq!(st.classes[LinkClass::Intra.idx()].bytes, 0);
        assert_eq!(st.classes[LinkClass::Inter.idx()].bytes, 35);
        assert_eq!(st.classes[LinkClass::Inter.idx()].busiest, 30);
    }

    #[test]
    fn classed_matrix_splits_by_placement() {
        // 2 nodes × 2 ranks; intra 10× the inter bandwidth, zero α.
        let topo = Topology::two_level(
            2,
            2,
            LinkKind::Custom(80, 0), // 10 B/s
            LinkKind::Custom(8, 0),  // 1 B/s
        );
        let net = Network::with_topology(topo);
        // 0→1 co-located (100 B), 0→2 cross-node (40 B).
        let m = vec![
            vec![0, 100, 40, 0],
            vec![0; 4],
            vec![0; 4],
            vec![0; 4],
        ];
        let st = net.stage_from_matrix("mixed", &m);
        let intra = &st.classes[LinkClass::Intra.idx()];
        let inter = &st.classes[LinkClass::Inter.idx()];
        assert_eq!(intra.bytes, 100);
        assert_eq!(inter.bytes, 40);
        assert_eq!(intra.busiest, 100);
        assert_eq!(inter.busiest, 40);
        // classes run in parallel: intra 100/10 = 10 s, inter 40/1 = 40 s
        assert!((intra.time - 10.0).abs() < 1e-9);
        assert!((inter.time - 40.0).abs() < 1e-9);
        assert!((st.time - 40.0).abs() < 1e-9, "stage = max over classes");
        // total sent/recv vectors are class-agnostic
        assert_eq!(st.sent, vec![140, 0, 0, 0]);
        assert_eq!(st.recv, vec![0, 100, 40, 0]);
    }

    #[test]
    fn idle_class_charges_no_latency() {
        let topo = Topology::two_level(2, 2, LinkKind::NvLink, LinkKind::Tcp25);
        let net = Network::with_topology(topo);
        assert_eq!(net.class_time(LinkClass::Intra, 0), 0.0);
        assert!(net.class_time(LinkClass::Inter, 1) > 0.0);
    }

}
