//! Communication accounting: per-stage and per-synchronization reports.

use super::topology::{LinkClass, LINK_CLASSES};

/// One link class's share of a stage: total bytes it carried, the
/// busiest endpoint's bytes on it, and its α–β time. The stage's time
/// is the max over classes (parallel physical links); a flat network
/// puts everything in the inter class.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ClassStage {
    /// Total bytes moved on this class in the stage.
    pub bytes: u64,
    /// Busiest endpoint's `max(sent, recv)` on this class.
    pub busiest: u64,
    /// α–β time of this class (0 when it carried nothing).
    pub time: f64,
}

/// One synchronous communication stage.
#[derive(Clone, Debug)]
pub struct StageReport {
    pub name: String,
    /// Bytes sent by each endpoint in this stage (all classes).
    pub sent: Vec<u64>,
    /// Bytes received by each endpoint in this stage (all classes).
    pub recv: Vec<u64>,
    /// Virtual time charged for the stage (seconds) — the max over the
    /// per-class times in `classes`.
    pub time: f64,
    /// Per-link-class split, indexed by [`LinkClass::idx`]
    /// (`[intra, inter]`).
    pub classes: [ClassStage; 2],
}

impl StageReport {
    /// Build a flat-network stage: all traffic on the inter class —
    /// the historical constructor for code and tests that never split
    /// by placement.
    pub fn flat(name: &str, sent: Vec<u64>, recv: Vec<u64>, time: f64) -> StageReport {
        let busiest = sent
            .iter()
            .zip(recv.iter())
            .map(|(&s, &r)| s.max(r))
            .max()
            .unwrap_or(0);
        let mut classes = [ClassStage::default(); 2];
        classes[LinkClass::Inter.idx()] = ClassStage {
            bytes: sent.iter().sum(),
            busiest,
            time,
        };
        StageReport {
            name: name.to_string(),
            sent,
            recv,
            time,
            classes,
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.sent.iter().sum()
    }

    fn imbalance(values: &[u64]) -> f64 {
        let total: u64 = values.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let max = values.iter().copied().max().unwrap_or(0);
        max as f64 * values.len() as f64 / total as f64
    }

    /// `n · max_recv / total_recv` for this stage (Definition 6 when the
    /// stage is a Push: receivers are the servers).
    pub fn recv_imbalance(&self) -> f64 {
        Self::imbalance(&self.recv)
    }

    /// `n · max_sent / total_sent` (Definition 6 for Pull: the servers
    /// are the senders).
    pub fn sent_imbalance(&self) -> f64 {
        Self::imbalance(&self.sent)
    }
}

/// Full report for one synchronization of one tensor.
#[derive(Clone, Debug, Default)]
pub struct CommReport {
    pub stages: Vec<StageReport>,
    /// CPU/GPU-side computation overhead charged by the scheme
    /// (e.g. Zen's hashing, format encode/decode), in seconds.
    pub compute_overhead: f64,
}

impl CommReport {
    pub fn new() -> Self {
        CommReport::default()
    }

    pub fn push(&mut self, stage: StageReport) {
        self.stages.push(stage);
    }

    /// Total virtual communication time (sum of synchronous stages).
    pub fn comm_time(&self) -> f64 {
        self.stages.iter().map(|s| s.time).sum()
    }

    /// Total synchronization time including scheme compute overhead.
    pub fn total_time(&self) -> f64 {
        self.comm_time() + self.compute_overhead
    }

    /// Total bytes put on the network.
    pub fn total_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.total_bytes()).sum()
    }

    /// Largest number of bytes received by any endpoint in any stage —
    /// the hotspot metric that the balance dimension controls.
    pub fn max_stage_recv(&self) -> u64 {
        self.stages
            .iter()
            .flat_map(|s| s.recv.iter().copied())
            .max()
            .unwrap_or(0)
    }

    /// Per-endpoint total received bytes across all stages.
    pub fn recv_per_endpoint(&self) -> Vec<u64> {
        if self.stages.is_empty() {
            return Vec::new();
        }
        let n = self.stages[0].recv.len();
        let mut out = vec![0u64; n];
        for s in &self.stages {
            for (o, &r) in out.iter_mut().zip(s.recv.iter()) {
                *o += r;
            }
        }
        out
    }

    /// Receive-imbalance across endpoints: `n · max_recv / total_recv`
    /// (1.0 = perfectly balanced).
    pub fn recv_imbalance(&self) -> f64 {
        let per = self.recv_per_endpoint();
        let total: u64 = per.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let max = per.iter().copied().max().unwrap_or(0);
        max as f64 * per.len() as f64 / total as f64
    }

    /// Total bytes per link class across all stages (`[intra, inter]`).
    pub fn bytes_by_class(&self) -> [u64; 2] {
        let mut out = [0u64; 2];
        for s in &self.stages {
            for c in LINK_CLASSES {
                out[c.idx()] += s.classes[c.idx()].bytes;
            }
        }
        out
    }

    /// Virtual time per link class across all stages (`[intra, inter]`).
    /// The sums can each be below [`comm_time`](CommReport::comm_time):
    /// a stage charges the max over its classes, not their sum.
    pub fn time_by_class(&self) -> [f64; 2] {
        let mut out = [0f64; 2];
        for s in &self.stages {
            for c in LINK_CLASSES {
                out[c.idx()] += s.classes[c.idx()].time;
            }
        }
        out
    }

    /// Merge another report's stages and overhead into this one
    /// (sequential composition, e.g. Push then Pull).
    pub fn extend(&mut self, other: CommReport) {
        self.stages.extend(other.stages);
        self.compute_overhead += other.compute_overhead;
    }
}

/// A communication job waiting to be placed on the shared link:
/// it becomes `ready` at a virtual time (its gradients exist from that
/// point on) and occupies the link for `duration` seconds.
#[derive(Clone, Debug)]
pub struct TimelineJob {
    pub label: String,
    /// Virtual time at which the payload is ready to transmit.
    pub ready: f64,
    /// Link occupancy (seconds of virtual communication time).
    pub duration: f64,
    /// Bytes this job puts on the network (reporting only).
    pub bytes: u64,
    /// Forward-pass consumption rank (0 = needed first in the next
    /// iteration's forward pass). Used by the priority schedulers to
    /// break ties among simultaneously-ready jobs, and by
    /// [`Timeline::forward_finish`] to order forward consumption.
    pub priority: usize,
    /// Forward-pass compute time (seconds) of the layers this job
    /// carries — how long the next iteration's forward pass spends on
    /// them once their gradients have arrived.
    pub fwd_duration: f64,
}

/// One scheduled interval on the shared inter-machine link.
#[derive(Clone, Debug)]
pub struct TimelineEntry {
    pub label: String,
    pub ready: f64,
    pub start: f64,
    pub finish: f64,
    pub bytes: u64,
    /// Forward-consumption rank inherited from the job (0 = first).
    pub priority: usize,
    /// Forward-pass compute time inherited from the job.
    pub fwd_duration: f64,
}

/// Virtual-time schedule of communication jobs overlapping one compute
/// pass — the accounting behind the engine's serialized-vs-overlapped
/// iteration times. Jobs share a single full-duplex fabric, so they run
/// back-to-back in order; job *k* starts at `max(ready_k, finish_{k-1})`.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    pub entries: Vec<TimelineEntry>,
    /// Modeled compute (backward-pass) time the jobs overlap with.
    pub compute_time: f64,
}

/// A communication job with its link occupancy split per
/// [`LinkClass`] (`[intra, inter]`) — built from a bucket report's
/// [`time_by_class`](CommReport::time_by_class). Input to
/// [`Timeline::schedule_classed`].
#[derive(Clone, Debug)]
pub struct ClassedJob {
    pub label: String,
    /// Virtual time at which the payload is ready to transmit.
    pub ready: f64,
    /// Link occupancy per class (seconds); a class the job never
    /// touches carries `0.0` and does not constrain it.
    pub durations: [f64; 2],
    /// Bytes this job puts on the network (reporting only).
    pub bytes: u64,
    /// Forward-pass consumption rank (0 = needed first); see
    /// [`TimelineJob::priority`].
    pub priority: usize,
    /// Forward-pass compute time of the carried layers; see
    /// [`TimelineJob::fwd_duration`].
    pub fwd_duration: f64,
}

impl Timeline {
    /// Greedy in-order schedule of `jobs` against a `compute_time`-long
    /// compute pass.
    pub fn schedule(compute_time: f64, jobs: &[TimelineJob]) -> Timeline {
        let mut entries = Vec::with_capacity(jobs.len());
        let mut cursor = 0.0f64;
        for job in jobs {
            let start = job.ready.max(cursor);
            let finish = start + job.duration;
            cursor = finish;
            entries.push(TimelineEntry {
                label: job.label.clone(),
                ready: job.ready,
                start,
                finish,
                bytes: job.bytes,
                priority: job.priority,
                fwd_duration: job.fwd_duration,
            });
        }
        Timeline {
            entries,
            compute_time,
        }
    }

    /// Priority (first-needed-first) schedule on the single shared
    /// link: among the jobs that are ready, always transmit the one
    /// whose layers the *next* iteration's forward pass consumes
    /// earliest (lowest [`TimelineJob::priority`]), à la ByteScheduler.
    /// Repeatedly picks the job minimizing the lexicographic key
    /// `(feasible start, priority, submission index)` — so an idle link
    /// never waits for a higher-priority job that is not ready yet
    /// (work conservation: the busy periods, and hence the makespan,
    /// match [`schedule`](Timeline::schedule) exactly when ready times
    /// are monotone in submission order). The payoff is in
    /// [`forward_finish`](Timeline::forward_finish): once a backlog
    /// forms, the first-needed bucket jumps the queue and the next
    /// forward pass stalls less.
    pub fn schedule_priority(compute_time: f64, jobs: &[TimelineJob]) -> Timeline {
        let mut entries = Vec::with_capacity(jobs.len());
        let mut done = vec![false; jobs.len()];
        let mut cursor = 0.0f64;
        for _ in 0..jobs.len() {
            let mut best: Option<(f64, usize, usize)> = None;
            for (i, job) in jobs.iter().enumerate() {
                if done[i] {
                    continue;
                }
                let key = (job.ready.max(cursor), job.priority, i);
                if best.is_none_or(|b| key < b) {
                    best = Some(key);
                }
            }
            let (start, _, i) = best.expect("one undone job must remain");
            done[i] = true;
            let job = &jobs[i];
            let finish = start + job.duration;
            cursor = finish;
            entries.push(TimelineEntry {
                label: job.label.clone(),
                ready: job.ready,
                start,
                finish,
                bytes: job.bytes,
                priority: job.priority,
                fwd_duration: job.fwd_duration,
            });
        }
        Timeline {
            entries,
            compute_time,
        }
    }

    /// Link-busy-interval schedule: each [`LinkClass`] is its own
    /// physical resource with a busy-until cursor. A job starts once it
    /// is ready *and* every class it occupies is free, holds each class
    /// for that class's duration, and finishes when its slowest class
    /// does — so an intra-only bucket overlaps freely with an
    /// inter-heavy one instead of queuing behind it. On a flat network
    /// every job occupies only the inter class and this reduces exactly
    /// to [`schedule`](Timeline::schedule). This is the engine's
    /// pipelined-bucket model under the event-driven virtual-time
    /// transport, replacing thread-join ordering with simulated link
    /// contention.
    pub fn schedule_classed(compute_time: f64, jobs: &[ClassedJob]) -> Timeline {
        let mut entries = Vec::with_capacity(jobs.len());
        let mut cursors = [0.0f64; 2];
        for job in jobs {
            let mut start = job.ready;
            for c in LINK_CLASSES {
                if job.durations[c.idx()] > 0.0 {
                    start = start.max(cursors[c.idx()]);
                }
            }
            let mut finish = start;
            for c in LINK_CLASSES {
                let d = job.durations[c.idx()];
                if d > 0.0 {
                    cursors[c.idx()] = start + d;
                    finish = finish.max(start + d);
                }
            }
            entries.push(TimelineEntry {
                label: job.label.clone(),
                ready: job.ready,
                start,
                finish,
                bytes: job.bytes,
                priority: job.priority,
                fwd_duration: job.fwd_duration,
            });
        }
        Timeline {
            entries,
            compute_time,
        }
    }

    /// Priority schedule over per-class link resources — the classed
    /// sibling of [`schedule_priority`](Timeline::schedule_priority).
    /// A job's feasible start is the latest of its ready time and the
    /// busy-until cursors of every class it occupies; among feasible
    /// jobs the scheduler picks the lexicographic minimum of
    /// `(feasible start, priority, submission index)`. Unlike the
    /// single-link case, priority here can strictly shorten the
    /// *makespan* too: serving the first-needed job first can hand an
    /// intra-heavy and an inter-heavy job to disjoint links in an
    /// order the FIFO schedule would have serialized.
    pub fn schedule_classed_priority(compute_time: f64, jobs: &[ClassedJob]) -> Timeline {
        let mut entries = Vec::with_capacity(jobs.len());
        let mut done = vec![false; jobs.len()];
        let mut cursors = [0.0f64; 2];
        for _ in 0..jobs.len() {
            let mut best: Option<(f64, usize, usize)> = None;
            for (i, job) in jobs.iter().enumerate() {
                if done[i] {
                    continue;
                }
                let mut start = job.ready;
                for c in LINK_CLASSES {
                    if job.durations[c.idx()] > 0.0 {
                        start = start.max(cursors[c.idx()]);
                    }
                }
                let key = (start, job.priority, i);
                if best.is_none_or(|b| key < b) {
                    best = Some(key);
                }
            }
            let (start, _, i) = best.expect("one undone job must remain");
            done[i] = true;
            let job = &jobs[i];
            let mut finish = start;
            for c in LINK_CLASSES {
                let d = job.durations[c.idx()];
                if d > 0.0 {
                    cursors[c.idx()] = start + d;
                    finish = finish.max(start + d);
                }
            }
            entries.push(TimelineEntry {
                label: job.label.clone(),
                ready: job.ready,
                start,
                finish,
                bytes: job.bytes,
                priority: job.priority,
                fwd_duration: job.fwd_duration,
            });
        }
        Timeline {
            entries,
            compute_time,
        }
    }

    /// Total communication time (sum of link occupancy).
    pub fn comm_time(&self) -> f64 {
        self.entries.iter().map(|e| e.finish - e.start).sum()
    }

    /// Iteration time without overlap: compute, then every job in turn.
    pub fn serialized_time(&self) -> f64 {
        self.compute_time + self.comm_time()
    }

    /// Iteration time with overlap: the pipeline's makespan.
    pub fn overlapped_time(&self) -> f64 {
        let last = self.entries.last().map(|e| e.finish).unwrap_or(0.0);
        last.max(self.compute_time)
    }

    /// Communication time hidden behind compute, clamped at 0 (a job
    /// whose `ready` lies beyond `compute_time` can push the makespan
    /// past the serialized schedule).
    pub fn hidden_time(&self) -> f64 {
        (self.serialized_time() - self.overlapped_time()).max(0.0)
    }

    pub fn total_bytes(&self) -> u64 {
        self.entries.iter().map(|e| e.bytes).sum()
    }

    /// Virtual time at which the *next* iteration's forward pass
    /// completes. The forward pass starts when this iteration's
    /// backward compute ends (`compute_time`), consumes layers in
    /// ascending [`TimelineEntry::priority`] order, and spends each
    /// entry's `fwd_duration` on its layers — but cannot touch a layer
    /// before its synchronization `finish`es. This is the metric
    /// priority scheduling actually improves: on a single link the
    /// makespan is schedule-order-invariant (work conservation), but
    /// draining the backlog first-needed-first lets the forward pass
    /// start sooner.
    pub fn forward_finish(&self) -> f64 {
        let mut order: Vec<&TimelineEntry> = self.entries.iter().collect();
        order.sort_by_key(|e| e.priority);
        let mut t = self.compute_time;
        for e in order {
            t = t.max(e.finish) + e.fwd_duration;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage(name: &str, sent: Vec<u64>, recv: Vec<u64>, time: f64) -> StageReport {
        StageReport::flat(name, sent, recv, time)
    }

    #[test]
    fn totals_accumulate() {
        let mut r = CommReport::new();
        r.push(stage("a", vec![10, 0], vec![0, 10], 1.0));
        r.push(stage("b", vec![0, 4], vec![4, 0], 0.5));
        r.compute_overhead = 0.25;
        assert_eq!(r.total_bytes(), 14);
        assert!((r.comm_time() - 1.5).abs() < 1e-12);
        assert!((r.total_time() - 1.75).abs() < 1e-12);
        assert_eq!(r.max_stage_recv(), 10);
        assert_eq!(r.recv_per_endpoint(), vec![4, 10]);
        // flat stages land entirely in the inter class
        assert_eq!(r.bytes_by_class(), [0, 14]);
        let by_class = r.time_by_class();
        assert_eq!(by_class[LinkClass::Intra.idx()], 0.0);
        assert!((by_class[LinkClass::Inter.idx()] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn flat_stage_records_class_busiest() {
        let s = StageReport::flat("a", vec![10, 0], vec![0, 10], 1.0);
        let inter = &s.classes[LinkClass::Inter.idx()];
        assert_eq!(inter.bytes, 10);
        assert_eq!(inter.busiest, 10);
        assert_eq!(inter.time, 1.0);
        assert_eq!(s.classes[LinkClass::Intra.idx()].bytes, 0);
    }

    #[test]
    fn imbalance_metric() {
        let mut r = CommReport::new();
        r.push(stage("a", vec![0, 0], vec![30, 10], 1.0));
        // max 30, total 40, n=2 → 1.5
        assert!((r.recv_imbalance() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn empty_report_neutral() {
        let r = CommReport::new();
        assert_eq!(r.total_bytes(), 0);
        assert_eq!(r.comm_time(), 0.0);
        assert_eq!(r.recv_imbalance(), 1.0);
    }

    fn job(label: &str, ready: f64, duration: f64) -> TimelineJob {
        TimelineJob {
            label: label.into(),
            ready,
            duration,
            bytes: 100,
            priority: 0,
            fwd_duration: 0.0,
        }
    }

    fn pjob(label: &str, ready: f64, duration: f64, priority: usize, fwd: f64) -> TimelineJob {
        TimelineJob {
            priority,
            fwd_duration: fwd,
            ..job(label, ready, duration)
        }
    }

    #[test]
    fn timeline_overlap_hides_early_jobs() {
        // compute = 1.0; job a ready at 0.5 (dur 0.2), b ready at 1.0
        // (dur 0.3): a hides fully, finish = 1.3 vs serialized 1.5.
        let t = Timeline::schedule(1.0, &[job("a", 0.5, 0.2), job("b", 1.0, 0.3)]);
        assert!((t.serialized_time() - 1.5).abs() < 1e-12);
        assert!((t.overlapped_time() - 1.3).abs() < 1e-12);
        assert!((t.hidden_time() - 0.2).abs() < 1e-12);
        assert_eq!(t.total_bytes(), 200);
    }

    #[test]
    fn timeline_link_is_sequential() {
        // Two jobs ready at once: the second waits for the link.
        let t = Timeline::schedule(0.0, &[job("a", 0.0, 0.4), job("b", 0.0, 0.4)]);
        assert!((t.entries[1].start - 0.4).abs() < 1e-12);
        assert!((t.overlapped_time() - 0.8).abs() < 1e-12);
        // nothing to hide without compute
        assert!(t.hidden_time().abs() < 1e-12);
    }

    #[test]
    fn timeline_no_jobs_is_pure_compute() {
        let t = Timeline::schedule(0.7, &[]);
        assert!((t.overlapped_time() - 0.7).abs() < 1e-12);
        assert!((t.serialized_time() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn timeline_overlapped_never_exceeds_serialized() {
        let jobs = [job("a", 0.2, 0.5), job("b", 0.6, 0.1), job("c", 1.0, 0.4)];
        let t = Timeline::schedule(1.0, &jobs);
        assert!(t.overlapped_time() <= t.serialized_time() + 1e-12);
        assert!(t.overlapped_time() >= t.compute_time);
    }

    fn cjob(label: &str, ready: f64, durations: [f64; 2]) -> ClassedJob {
        ClassedJob {
            label: label.into(),
            ready,
            durations,
            bytes: 100,
            priority: 0,
            fwd_duration: 0.0,
        }
    }

    #[test]
    fn classed_schedule_reduces_to_flat_on_inter_only_jobs() {
        // Same jobs, inter class only: identical start/finish as the
        // single-cursor greedy schedule.
        let flat = Timeline::schedule(
            1.0,
            &[job("a", 0.5, 0.2), job("b", 0.6, 0.4), job("c", 1.2, 0.1)],
        );
        let classed = Timeline::schedule_classed(
            1.0,
            &[
                cjob("a", 0.5, [0.0, 0.2]),
                cjob("b", 0.6, [0.0, 0.4]),
                cjob("c", 1.2, [0.0, 0.1]),
            ],
        );
        for (f, c) in flat.entries.iter().zip(classed.entries.iter()) {
            assert_eq!(f.start, c.start, "{}", f.label);
            assert_eq!(f.finish, c.finish, "{}", f.label);
        }
        assert_eq!(flat.overlapped_time(), classed.overlapped_time());
    }

    #[test]
    fn classed_schedule_overlaps_disjoint_link_classes() {
        // An intra-only job and an inter-only job ready at once run
        // concurrently; a second inter job queues behind the first.
        let t = Timeline::schedule_classed(
            0.0,
            &[
                cjob("inter-1", 0.0, [0.0, 0.4]),
                cjob("intra", 0.0, [0.3, 0.0]),
                cjob("inter-2", 0.0, [0.0, 0.2]),
            ],
        );
        assert_eq!(t.entries[0].start, 0.0);
        assert_eq!(t.entries[1].start, 0.0, "intra link is free");
        assert!((t.entries[2].start - 0.4).abs() < 1e-12, "inter is busy");
        assert!((t.overlapped_time() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn classed_job_finishes_with_its_slowest_class() {
        let t = Timeline::schedule_classed(0.0, &[cjob("both", 0.1, [0.5, 0.2])]);
        assert!((t.entries[0].finish - 0.6).abs() < 1e-12);
        // both cursors advance: a follow-up on either class waits
        let t2 = Timeline::schedule_classed(
            0.0,
            &[cjob("both", 0.0, [0.5, 0.2]), cjob("intra", 0.0, [0.1, 0.0])],
        );
        assert!((t2.entries[1].start - 0.5).abs() < 1e-12);
    }

    #[test]
    fn priority_single_link_makespan_matches_greedy() {
        // Monotone ready times (the backward pass emits buckets in
        // order): both schedules are work-conserving on one link, so
        // their busy periods — and the makespan — are identical even
        // though the priority schedule transmits in a different order.
        let jobs = [
            pjob("mlp0", 0.2, 0.4, 3, 0.25),
            pjob("mlp1", 0.4, 0.4, 2, 0.25),
            pjob("mlp2", 0.6, 0.4, 1, 0.25),
            pjob("emb", 0.8, 0.4, 0, 0.25),
        ];
        let greedy = Timeline::schedule(1.0, &jobs);
        let prio = Timeline::schedule_priority(1.0, &jobs);
        assert!((greedy.overlapped_time() - prio.overlapped_time()).abs() < 1e-12);
        assert!((greedy.serialized_time() - prio.serialized_time()).abs() < 1e-12);
        assert_eq!(greedy.total_bytes(), prio.total_bytes());
    }

    #[test]
    fn priority_backlog_improves_forward_finish() {
        // Backward completion order is the reverse of forward need:
        // by the time the link drains the backlog, greedy sends the
        // first-needed bucket (emb, priority 0) last, while the
        // priority schedule jumps it to the front of the queue. Same
        // makespan, strictly earlier next-iteration forward finish.
        let jobs = [
            pjob("mlp0", 0.2, 0.4, 3, 0.25),
            pjob("mlp1", 0.4, 0.4, 2, 0.25),
            pjob("mlp2", 0.6, 0.4, 1, 0.25),
            pjob("emb", 0.8, 0.4, 0, 0.25),
        ];
        let greedy = Timeline::schedule(1.0, &jobs);
        let prio = Timeline::schedule_priority(1.0, &jobs);
        // greedy: emb finishes last at 1.8 → fwd = 1.8 + 4·0.25
        assert!((greedy.forward_finish() - 2.8).abs() < 1e-12);
        // priority: emb sent third (1.0–1.4), mlp1 absorbs the delay
        assert!((prio.forward_finish() - 2.4).abs() < 1e-12);
        assert!(prio.forward_finish() < greedy.forward_finish());
    }

    #[test]
    fn priority_is_work_conserving() {
        // The link never idles waiting for a higher-priority job that
        // is not ready yet: the ready lower-priority job goes first.
        let jobs = [pjob("low", 0.0, 0.5, 1, 0.0), pjob("high", 0.2, 0.1, 0, 0.0)];
        let t = Timeline::schedule_priority(0.0, &jobs);
        assert_eq!(t.entries[0].label, "low");
        assert!((t.entries[1].start - 0.5).abs() < 1e-12);
        assert!((t.overlapped_time() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn forward_finish_without_fwd_cost_is_overlapped_time() {
        let jobs = [job("a", 0.5, 0.2), job("b", 1.0, 0.3)];
        let t = Timeline::schedule(1.0, &jobs);
        assert!((t.forward_finish() - t.overlapped_time()).abs() < 1e-12);
        let empty = Timeline::schedule(0.7, &[]);
        assert!((empty.forward_finish() - 0.7).abs() < 1e-12);
    }

    fn pcjob(label: &str, ready: f64, durations: [f64; 2], priority: usize) -> ClassedJob {
        ClassedJob {
            priority,
            ..cjob(label, ready, durations)
        }
    }

    #[test]
    fn classed_priority_reduces_to_priority_on_inter_only_jobs() {
        let jobs = [
            pjob("a", 0.2, 0.4, 2, 0.1),
            pjob("b", 0.3, 0.2, 0, 0.1),
            pjob("c", 0.3, 0.3, 1, 0.1),
        ];
        let cjobs: Vec<ClassedJob> = jobs
            .iter()
            .map(|j| ClassedJob {
                label: j.label.clone(),
                ready: j.ready,
                durations: [0.0, j.duration],
                bytes: j.bytes,
                priority: j.priority,
                fwd_duration: j.fwd_duration,
            })
            .collect();
        let flat = Timeline::schedule_priority(1.0, &jobs);
        let classed = Timeline::schedule_classed_priority(1.0, &cjobs);
        for (f, c) in flat.entries.iter().zip(classed.entries.iter()) {
            assert_eq!(f.label, c.label);
            assert_eq!(f.start, c.start, "{}", f.label);
            assert_eq!(f.finish, c.finish, "{}", f.label);
        }
        assert_eq!(flat.forward_finish(), classed.forward_finish());
    }

    #[test]
    fn classed_priority_can_beat_fifo_makespan() {
        // FIFO head-of-line blocking across link classes: the
        // both-class job queues behind the intra job AND delays the
        // inter job. Serving first-needed-first hands the intra-only
        // and inter-only jobs to their disjoint links immediately.
        let jobs = [
            pcjob("intra", 0.0, [0.5, 0.0], 2),
            pcjob("both", 0.0, [0.4, 0.4], 1),
            pcjob("inter", 0.0, [0.0, 0.5], 0),
        ];
        let fifo = Timeline::schedule_classed(0.0, &jobs);
        let prio = Timeline::schedule_classed_priority(0.0, &jobs);
        assert!((fifo.overlapped_time() - 1.4).abs() < 1e-12);
        assert!((prio.overlapped_time() - 0.9).abs() < 1e-12);
    }
}
