//! Communication accounting: per-stage and per-synchronization reports.

/// One synchronous communication stage.
#[derive(Clone, Debug)]
pub struct StageReport {
    pub name: String,
    /// Bytes sent by each endpoint in this stage.
    pub sent: Vec<u64>,
    /// Bytes received by each endpoint in this stage.
    pub recv: Vec<u64>,
    /// Virtual time charged for the stage (seconds).
    pub time: f64,
}

impl StageReport {
    pub fn total_bytes(&self) -> u64 {
        self.sent.iter().sum()
    }

    fn imbalance(values: &[u64]) -> f64 {
        let total: u64 = values.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let max = values.iter().copied().max().unwrap_or(0);
        max as f64 * values.len() as f64 / total as f64
    }

    /// `n · max_recv / total_recv` for this stage (Definition 6 when the
    /// stage is a Push: receivers are the servers).
    pub fn recv_imbalance(&self) -> f64 {
        Self::imbalance(&self.recv)
    }

    /// `n · max_sent / total_sent` (Definition 6 for Pull: the servers
    /// are the senders).
    pub fn sent_imbalance(&self) -> f64 {
        Self::imbalance(&self.sent)
    }
}

/// Full report for one synchronization of one tensor.
#[derive(Clone, Debug, Default)]
pub struct CommReport {
    pub stages: Vec<StageReport>,
    /// CPU/GPU-side computation overhead charged by the scheme
    /// (e.g. Zen's hashing, format encode/decode), in seconds.
    pub compute_overhead: f64,
}

impl CommReport {
    pub fn new() -> Self {
        CommReport::default()
    }

    pub fn push(&mut self, stage: StageReport) {
        self.stages.push(stage);
    }

    /// Total virtual communication time (sum of synchronous stages).
    pub fn comm_time(&self) -> f64 {
        self.stages.iter().map(|s| s.time).sum()
    }

    /// Total synchronization time including scheme compute overhead.
    pub fn total_time(&self) -> f64 {
        self.comm_time() + self.compute_overhead
    }

    /// Total bytes put on the network.
    pub fn total_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.total_bytes()).sum()
    }

    /// Largest number of bytes received by any endpoint in any stage —
    /// the hotspot metric that the balance dimension controls.
    pub fn max_stage_recv(&self) -> u64 {
        self.stages
            .iter()
            .flat_map(|s| s.recv.iter().copied())
            .max()
            .unwrap_or(0)
    }

    /// Per-endpoint total received bytes across all stages.
    pub fn recv_per_endpoint(&self) -> Vec<u64> {
        if self.stages.is_empty() {
            return Vec::new();
        }
        let n = self.stages[0].recv.len();
        let mut out = vec![0u64; n];
        for s in &self.stages {
            for (o, &r) in out.iter_mut().zip(s.recv.iter()) {
                *o += r;
            }
        }
        out
    }

    /// Receive-imbalance across endpoints: `n · max_recv / total_recv`
    /// (1.0 = perfectly balanced).
    pub fn recv_imbalance(&self) -> f64 {
        let per = self.recv_per_endpoint();
        let total: u64 = per.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let max = per.iter().copied().max().unwrap_or(0);
        max as f64 * per.len() as f64 / total as f64
    }

    /// Merge another report's stages and overhead into this one
    /// (sequential composition, e.g. Push then Pull).
    pub fn extend(&mut self, other: CommReport) {
        self.stages.extend(other.stages);
        self.compute_overhead += other.compute_overhead;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage(name: &str, sent: Vec<u64>, recv: Vec<u64>, time: f64) -> StageReport {
        StageReport {
            name: name.into(),
            sent,
            recv,
            time,
        }
    }

    #[test]
    fn totals_accumulate() {
        let mut r = CommReport::new();
        r.push(stage("a", vec![10, 0], vec![0, 10], 1.0));
        r.push(stage("b", vec![0, 4], vec![4, 0], 0.5));
        r.compute_overhead = 0.25;
        assert_eq!(r.total_bytes(), 14);
        assert!((r.comm_time() - 1.5).abs() < 1e-12);
        assert!((r.total_time() - 1.75).abs() < 1e-12);
        assert_eq!(r.max_stage_recv(), 10);
        assert_eq!(r.recv_per_endpoint(), vec![4, 10]);
    }

    #[test]
    fn imbalance_metric() {
        let mut r = CommReport::new();
        r.push(stage("a", vec![0, 0], vec![30, 10], 1.0));
        // max 30, total 40, n=2 → 1.5
        assert!((r.recv_imbalance() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn empty_report_neutral() {
        let r = CommReport::new();
        assert_eq!(r.total_bytes(), 0);
        assert_eq!(r.comm_time(), 0.0);
        assert_eq!(r.recv_imbalance(), 1.0);
    }
}
