//! Summary statistics and histograms for measurement reporting.

/// Online + batch summary of a sample of f64 observations.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    values: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Summary::default()
    }

    pub fn from_values(values: Vec<f64>) -> Self {
        Summary {
            values,
            sorted: false,
        }
    }

    pub fn add(&mut self, v: f64) {
        self.values.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn variance(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.values
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let n = self.values.len();
        if n == 1 {
            return self.values[0];
        }
        let rank = (p / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.values[lo] * (1.0 - frac) + self.values[hi] * frac
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    /// One-line report string used by the bench harness.
    pub fn report(&mut self) -> String {
        format!(
            "n={} mean={:.6e} p50={:.6e} p95={:.6e} min={:.6e} max={:.6e}",
            self.len(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.min(),
            self.max()
        )
    }
}

/// Fixed-width histogram over [lo, hi) for distribution figures (Fig 1a).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            total: 0,
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, v: f64) {
        self.total += 1;
        if v < self.lo {
            self.underflow += 1;
        } else if v >= self.hi {
            self.overflow += 1;
        } else {
            let bins = self.counts.len();
            let idx = ((v - self.lo) / (self.hi - self.lo) * bins as f64) as usize;
            let idx = idx.min(bins - 1);
            self.counts[idx] += 1;
        }
    }

    /// Probability density per bin (integrates to the in-range mass).
    pub fn pdf(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        let n = self.total.max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / n / w).collect()
    }

    /// Bin centers, aligned with `pdf()`.
    pub fn centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| self.lo + (i as f64 + 0.5) * w)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(v);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.variance() - 2.5).abs() < 1e-12);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::from_values(vec![0.0, 10.0]);
        assert!((s.percentile(25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_nan() {
        let mut s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn histogram_mass() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        for i in 0..100 {
            h.add(i as f64 / 100.0);
        }
        assert_eq!(h.total, 100);
        assert_eq!(h.counts.iter().sum::<u64>(), 100);
        assert_eq!(h.underflow + h.overflow, 0);
        let pdf = h.pdf();
        let mass: f64 = pdf.iter().map(|p| p * 0.1).sum();
        assert!((mass - 1.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_out_of_range() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.add(-0.5);
        h.add(1.5);
        h.add(0.5);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.counts.iter().sum::<u64>(), 1);
    }
}
