//! Self-contained utility substrate.
//!
//! The offline crate registry only carries the `xla` closure, so everything
//! a framework normally pulls from crates.io (rand, rayon, criterion,
//! proptest, serde) is implemented here from scratch: a PCG64 RNG and Zipf
//! sampler, summary statistics, a scoped thread pool, a seeded
//! property-testing harness, wall-clock timers, and table rendering.

pub mod arena;
pub mod pool;
pub mod propcheck;
pub mod radix;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;

pub use arena::{OnceMap, ScratchPool};
pub use pool::ThreadPool;
pub use rng::{Pcg64, Zipf};
pub use stats::Summary;
pub use timer::Stopwatch;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Largest power of two ≤ `n` (`n ≥ 1`) — the recursive-doubling core
/// size shared by the folded schemes (SparCML, AGsparse-hier) and
/// their cost-model twins, so the schedules cannot drift apart.
#[inline]
pub fn largest_pow2_at_most(n: usize) -> usize {
    debug_assert!(n >= 1);
    1usize << (usize::BITS - 1 - n.leading_zeros())
}

/// Human-readable byte count.
pub fn human_bytes(b: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KB", "MB", "GB", "TB", "PB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2}{}", UNITS[u])
}

/// Human-readable duration in seconds.
pub fn human_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512.0), "512.00B");
        assert_eq!(human_bytes(2048.0), "2.00KB");
        assert!(human_bytes(3.5 * 1024.0 * 1024.0).ends_with("MB"));
    }

    #[test]
    fn human_secs_units() {
        assert!(human_secs(2e-9).ends_with("ns"));
        assert!(human_secs(2e-5).ends_with("us"));
        assert!(human_secs(2e-2).ends_with("ms"));
        assert!(human_secs(2.0).ends_with('s'));
    }
}
