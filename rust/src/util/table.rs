//! Markdown / CSV table rendering for experiment reports.
//!
//! Every figure generator produces a [`Table`]; `examples/figures.rs`
//! renders them to `reports/*.csv` and markdown blocks in EXPERIMENTS.md.

use std::fmt::Write as _;

/// A simple column-oriented table of strings.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Add a row; panics if the arity mismatches the header.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
    }

    /// Convenience: numeric row with fixed formatting.
    pub fn rowf(&mut self, cells: &[f64]) {
        self.row(cells.iter().map(|v| format!("{v:.6}")).collect());
    }

    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "### {}\n", self.title);
        let _ = writeln!(s, "| {} |", self.headers.join(" | "));
        let _ = writeln!(
            s,
            "|{}|",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(
            s,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(
                s,
                "{}",
                r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        s
    }

    /// Write CSV into `reports/<slug>.csv` under the repo root.
    pub fn save_csv(&self, dir: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let slug: String = self
            .title
            .to_lowercase()
            .chars()
            .map(|c| if c.is_alphanumeric() { c } else { '_' })
            .collect();
        let path = dir.join(format!("{slug}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("T", &["x"]);
        t.row(vec!["has,comma".into()]);
        assert!(t.to_csv().contains("\"has,comma\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn rowf_formats() {
        let mut t = Table::new("T", &["v"]);
        t.rowf(&[1.5]);
        assert!(t.rows[0][0].starts_with("1.5"));
    }
}
