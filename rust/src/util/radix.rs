//! LSD radix sort for (u32 key, f32 value) pairs.
//!
//! The hierarchical hasher's extraction phase sorts each partition's
//! (index, gradient) pairs; comparison sorting was ~30% of Algorithm 1's
//! wall time in the first perf pass. Up to four 8-bit passes with
//! counting buckets, skipping any pass whose keys all share one bucket —
//! tensor indices under 2²⁴ take at most three scatter passes, and the
//! 256-entry count tables keep a [`RadixScratch`] at ~2 KiB so one can
//! be embedded per partition shard without the resident-memory blowup a
//! 16-bit digit (two 256 KiB tables each) would cost at
//! workers × partitions scale.

/// Reusable buffers for [`radix_sort_pairs_with`]. After the first sort
/// at steady-state size, subsequent sorts perform no heap allocation —
/// part of the scratch-arena layer (see [`crate::util::arena`]).
#[derive(Debug, Default)]
pub struct RadixScratch {
    kbuf: Vec<u32>,
    vbuf: Vec<f32>,
    counts: Vec<u32>,
    offsets: Vec<u32>,
}

/// Sort `keys`/`vals` in tandem by ascending key. Stable. O(n) extra.
pub fn radix_sort_pairs(keys: &mut Vec<u32>, vals: &mut Vec<f32>) {
    radix_sort_pairs_with(keys, vals, &mut RadixScratch::default());
}

/// Sort `keys`/`vals` in tandem by ascending key, reusing `scratch`'s
/// buffers. Stable; allocation-free once the scratch has warmed up to
/// the working-set size.
pub fn radix_sort_pairs_with(keys: &mut Vec<u32>, vals: &mut Vec<f32>, scratch: &mut RadixScratch) {
    let n = keys.len();
    debug_assert_eq!(n, vals.len());
    if n <= 64 {
        // Tiny partitions: in-place insertion sort — no temporaries at
        // all, and faster than a counting pass at this size.
        for i in 1..n {
            let mut j = i;
            while j > 0 && keys[j - 1] > keys[j] {
                keys.swap(j - 1, j);
                vals.swap(j - 1, j);
                j -= 1;
            }
        }
        return;
    }
    const RADIX_BITS: usize = 8;
    const BUCKETS: usize = 1 << RADIX_BITS;
    const MASK: u32 = (BUCKETS - 1) as u32;
    // Size-only resize (no clear): every scatter pass overwrites all n
    // slots before they are read, so stale contents are never observed.
    scratch.kbuf.resize(n, 0);
    scratch.vbuf.resize(n, 0.0);
    scratch.counts.resize(BUCKETS, 0);
    scratch.offsets.resize(BUCKETS, 0);
    // One pass per byte, least-significant first.
    for pass in 0..4 {
        let shift = pass * RADIX_BITS;
        let counts: &mut [u32; BUCKETS] = (&mut scratch.counts[..BUCKETS]).try_into().unwrap();
        crate::kernel::active::histogram_u8(keys, shift as u32, counts);
        // skip a pass whose keys are all in one bucket
        if scratch.counts.iter().any(|&c| c as usize == n) {
            continue;
        }
        let mut acc = 0u32;
        for (o, &c) in scratch.offsets.iter_mut().zip(scratch.counts.iter()) {
            *o = acc;
            acc += c;
        }
        for i in 0..n {
            let b = ((keys[i] >> shift) & MASK) as usize;
            let dst = scratch.offsets[b] as usize;
            scratch.offsets[b] += 1;
            scratch.kbuf[dst] = keys[i];
            scratch.vbuf[dst] = vals[i];
        }
        std::mem::swap(keys, &mut scratch.kbuf);
        std::mem::swap(vals, &mut scratch.vbuf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, prop_assert};
    use crate::util::Pcg64;

    #[test]
    fn sorts_small_and_large() {
        for n in [0usize, 1, 5, 64, 65, 1_000, 100_000] {
            let mut rng = Pcg64::seeded(n as u64);
            let mut keys: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            let mut vals: Vec<f32> = keys.iter().map(|&k| k as f32 * 0.5).collect();
            radix_sort_pairs(&mut keys, &mut vals);
            assert!(keys.windows(2).all(|w| w[0] <= w[1]), "n={n}");
            // values stay paired with their keys
            for (k, v) in keys.iter().zip(vals.iter()) {
                assert_eq!(*v, *k as f32 * 0.5);
            }
        }
    }

    #[test]
    fn low_bits_only_fast_path() {
        // all keys < 65536 → the two high-byte passes are skipped
        let mut keys: Vec<u32> = (0..10_000u32).rev().collect();
        let mut vals: Vec<f32> = keys.iter().map(|&k| -(k as f32)).collect();
        radix_sort_pairs(&mut keys, &mut vals);
        assert!(keys.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(vals[0], 0.0);
    }

    #[test]
    fn scratch_reuse_across_sizes_and_shapes() {
        // One scratch serving shrinking, growing, and low-bit workloads
        // must never leak state between sorts.
        let mut scratch = RadixScratch::default();
        for (seed, n) in [(1u64, 5_000usize), (2, 100), (3, 80_000), (4, 63), (5, 70_000)] {
            let mut rng = Pcg64::seeded(seed);
            let mut keys: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            let mut vals: Vec<f32> = keys.iter().map(|&k| k as f32 * 0.25).collect();
            radix_sort_pairs_with(&mut keys, &mut vals, &mut scratch);
            assert!(keys.windows(2).all(|w| w[0] <= w[1]), "n={n}");
            for (k, v) in keys.iter().zip(vals.iter()) {
                assert_eq!(*v, *k as f32 * 0.25);
            }
        }
    }

    #[test]
    fn prop_matches_comparison_sort() {
        check(60, |g| {
            let n = g.usize_in(0, 2_000);
            let mut keys: Vec<u32> = (0..n).map(|_| g.u32_in(0, u32::MAX - 1)).collect();
            let mut vals: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let mut expect: Vec<(u32, f32)> =
                keys.iter().copied().zip(vals.iter().copied()).collect();
            expect.sort_by_key(|p| p.0);
            radix_sort_pairs(&mut keys, &mut vals);
            let got: Vec<(u32, f32)> = keys.into_iter().zip(vals).collect();
            // stable ties: compare keys only, then multiset of pairs
            let keys_match = got.iter().map(|p| p.0).eq(expect.iter().map(|p| p.0));
            prop_assert(keys_match, "radix keys == comparison keys")
        });
    }
}
