//! LSD radix sort for (u32 key, f32 value) pairs.
//!
//! The hierarchical hasher's extraction phase sorts each partition's
//! (index, gradient) pairs; comparison sorting was ~30% of Algorithm 1's
//! wall time in the first perf pass. Two 16-bit passes with counting
//! buckets are ~3–4× faster at the 10⁵–10⁶ element sizes partitions hit.

/// Sort `keys`/`vals` in tandem by ascending key. Stable. O(n) extra.
pub fn radix_sort_pairs(keys: &mut Vec<u32>, vals: &mut Vec<f32>) {
    let n = keys.len();
    debug_assert_eq!(n, vals.len());
    if n <= 64 {
        // tiny partitions: insertion-style via sort_unstable on pairs
        let mut pairs: Vec<(u32, f32)> = keys.iter().copied().zip(vals.iter().copied()).collect();
        pairs.sort_unstable_by_key(|p| p.0);
        for (i, (k, v)) in pairs.into_iter().enumerate() {
            keys[i] = k;
            vals[i] = v;
        }
        return;
    }
    let mut kbuf = vec![0u32; n];
    let mut vbuf = vec![0f32; n];
    // pass 1: low 16 bits; pass 2: high 16 bits
    for pass in 0..2 {
        let shift = pass * 16;
        let mut counts = vec![0u32; 1 << 16];
        for &k in keys.iter() {
            counts[((k >> shift) & 0xFFFF) as usize] += 1;
        }
        // skip a pass whose keys are all in one bucket
        if counts.iter().any(|&c| c as usize == n) {
            continue;
        }
        let mut offsets = vec![0u32; 1 << 16];
        let mut acc = 0u32;
        for (o, &c) in offsets.iter_mut().zip(counts.iter()) {
            *o = acc;
            acc += c;
        }
        for i in 0..n {
            let b = ((keys[i] >> shift) & 0xFFFF) as usize;
            let dst = offsets[b] as usize;
            offsets[b] += 1;
            kbuf[dst] = keys[i];
            vbuf[dst] = vals[i];
        }
        std::mem::swap(keys, &mut kbuf);
        std::mem::swap(vals, &mut vbuf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, prop_assert};
    use crate::util::Pcg64;

    #[test]
    fn sorts_small_and_large() {
        for n in [0usize, 1, 5, 64, 65, 1_000, 100_000] {
            let mut rng = Pcg64::seeded(n as u64);
            let mut keys: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            let mut vals: Vec<f32> = keys.iter().map(|&k| k as f32 * 0.5).collect();
            radix_sort_pairs(&mut keys, &mut vals);
            assert!(keys.windows(2).all(|w| w[0] <= w[1]), "n={n}");
            // values stay paired with their keys
            for (k, v) in keys.iter().zip(vals.iter()) {
                assert_eq!(*v, *k as f32 * 0.5);
            }
        }
    }

    #[test]
    fn low_bits_only_fast_path() {
        // all keys < 65536 → second pass skipped
        let mut keys: Vec<u32> = (0..10_000u32).rev().collect();
        let mut vals: Vec<f32> = keys.iter().map(|&k| -(k as f32)).collect();
        radix_sort_pairs(&mut keys, &mut vals);
        assert!(keys.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(vals[0], 0.0);
    }

    #[test]
    fn prop_matches_comparison_sort() {
        check(60, |g| {
            let n = g.usize_in(0, 2_000);
            let mut keys: Vec<u32> = (0..n).map(|_| g.u32_in(0, u32::MAX - 1)).collect();
            let mut vals: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let mut expect: Vec<(u32, f32)> =
                keys.iter().copied().zip(vals.iter().copied()).collect();
            expect.sort_by_key(|p| p.0);
            radix_sort_pairs(&mut keys, &mut vals);
            let got: Vec<(u32, f32)> = keys.into_iter().zip(vals).collect();
            // stable ties: compare keys only, then multiset of pairs
            let keys_match = got.iter().map(|p| p.0).eq(expect.iter().map(|p| p.0));
            prop_assert(keys_match, "radix keys == comparison keys")
        });
    }
}
