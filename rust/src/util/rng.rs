//! Deterministic pseudo-random number generation.
//!
//! PCG64 (permuted congruential generator, XSL-RR variant) — small, fast,
//! and statistically solid for simulation workloads. All experiment code
//! takes explicit seeds so every figure in the paper regenerates
//! bit-identically.

/// PCG64 XSL-RR generator with 128-bit state.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    /// Convenience constructor with stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
    }

    /// Next uniformly distributed u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let s = self.state;
        let xored = ((s >> 64) as u64) ^ (s as u64);
        let rot = (s >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates when
    /// k is large, rejection when small).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let x = self.below(n as u64) as usize;
                if seen.insert(x) {
                    out.push(x);
                }
            }
            out
        }
    }
}

/// Zipf(θ) sampler over [0, n): P(rank k) ∝ 1/(k+1)^θ, rank 0 most
/// frequent — the access distribution of embedding rows / vocabulary
/// tokens. Implemented with a precomputed CDF table + binary search:
/// O(n) memory once, O(log n) per sample, exactly the target law.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// `n`: support size, `theta`: exponent (> 0).
    pub fn new(n: usize, theta: f64) -> Self {
        assert!(n >= 1);
        assert!(theta > 0.0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 0..n {
            acc += (k as f64 + 1.0).powf(-theta);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Draw a rank in [0, n), rank 0 most probable.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }

    pub fn support(&self) -> usize {
        self.cdf.len()
    }

    /// CDF value at rank `k` (inclusive).
    pub fn cdf_at(&self, k: usize) -> f64 {
        self.cdf[k]
    }

    /// pmf at rank `k`.
    pub fn pmf_at(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_deterministic() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_support() {
        let mut r = Pcg64::seeded(9);
        let mut seen = [0u32; 7];
        for _ in 0..70_000 {
            seen[r.below(7) as usize] += 1;
        }
        for &c in &seen {
            // each bucket expected 10_000; loose 4-sigma band
            assert!((8_500..11_500).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(11);
        let n = 100_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Pcg64::seeded(13);
        for &(n, k) in &[(100usize, 10usize), (100, 90), (1000, 1)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn zipf_rank0_most_frequent() {
        let z = Zipf::new(1000, 1.1);
        let mut r = Pcg64::seeded(17);
        let mut counts = vec![0u32; 1000];
        for _ in 0..200_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[500].saturating_sub(5));
        // head mass dominates
        let head: u32 = counts[..10].iter().sum();
        let total: u32 = counts.iter().sum();
        assert!(head as f64 / total as f64 > 0.3);
    }

    #[test]
    fn zipf_support_bounds() {
        let z = Zipf::new(5, 0.8);
        let mut r = Pcg64::seeded(19);
        for _ in 0..10_000 {
            assert!(z.sample(&mut r) < 5);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(23);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
