//! Wall-clock timing and a micro-benchmark runner (criterion stand-in).

use std::time::Instant;

use super::stats::Summary;

/// Simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }

    /// Seconds elapsed since start.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Reset and return the previous elapsed seconds.
    pub fn lap(&mut self) -> f64 {
        let e = self.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Benchmark runner: warms up, then measures `iters` timed runs of `f`,
/// returning the per-run timing summary in seconds. Used by all
/// `rust/benches/*` harnesses (criterion is unavailable offline).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        s.add(t.elapsed().as_secs_f64());
    }
    let mut r = s.clone();
    println!("bench {name}: {}", r.report());
    s
}

/// Measure a single run's seconds.
pub fn time_once<R, F: FnOnce() -> R>(f: F) -> (R, f64) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let e = sw.lap();
        assert!(e >= 0.001);
        assert!(sw.elapsed() < e + 1.0);
    }

    #[test]
    fn bench_counts_iters() {
        let mut count = 0u32;
        let s = bench("noop", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
