//! A scoped work-stealing-free thread pool built on std.
//!
//! Stands in for rayon in the hashing hot path (Algorithm 1's parallel
//! hash phase) and in workload generation. `scoped_chunks` mirrors the
//! `par_chunks_mut` idiom: it splits a mutable slice into contiguous
//! chunks and runs the closure on each chunk from a worker thread.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Thread pool facade. Threads are spawned per `scope` invocation via
/// `std::thread::scope`, which keeps lifetimes simple (no 'static bound on
/// the work) at the cost of spawn overhead — amortized fine for the
/// multi-megabyte tensors this library processes.
#[derive(Clone, Debug)]
pub struct ThreadPool {
    workers: usize,
}

impl ThreadPool {
    /// Pool sized to available parallelism.
    pub fn new() -> Self {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        ThreadPool { workers }
    }

    /// Pool with an explicit worker count (min 1).
    pub fn with_workers(workers: usize) -> Self {
        ThreadPool {
            workers: workers.max(1),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f(chunk_index, chunk)` over contiguous chunks of `data`,
    /// in parallel across the pool.
    pub fn scoped_chunks<T: Send, F>(&self, data: &mut [T], chunk: usize, f: F)
    where
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk > 0);
        if self.workers == 1 || data.len() <= chunk {
            for (i, c) in data.chunks_mut(chunk).enumerate() {
                f(i, c);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk).enumerate().collect();
        let chunks = std::sync::Mutex::new(
            chunks
                .into_iter()
                .map(Some)
                .collect::<Vec<Option<(usize, &mut [T])>>>(),
        );
        std::thread::scope(|s| {
            for _ in 0..self.workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let job = {
                        let mut guard = chunks.lock().unwrap();
                        if i >= guard.len() {
                            return;
                        }
                        guard[i].take()
                    };
                    match job {
                        Some((ci, c)) => f(ci, c),
                        None => return,
                    }
                });
            }
        });
    }

    /// Parallel-for over index ranges: partitions [0, n) into `workers`
    /// contiguous ranges and runs `f(range)` on each.
    pub fn for_ranges<F>(&self, n: usize, f: F)
    where
        F: Fn(std::ops::Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        let w = self.workers.min(n);
        if w == 1 {
            f(0..n);
            return;
        }
        let per = crate::util::ceil_div(n, w);
        std::thread::scope(|s| {
            for t in 0..w {
                let lo = t * per;
                let hi = ((t + 1) * per).min(n);
                if lo >= hi {
                    break;
                }
                let f = &f;
                s.spawn(move || f(lo..hi));
            }
        });
    }

    /// Parallel map over owned items, preserving order.
    pub fn map<T: Send, R: Send, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        F: Fn(T) -> R + Sync,
    {
        if self.workers == 1 || items.len() <= 1 {
            return items.into_iter().map(f).collect();
        }
        let n = items.len();
        let slots: Vec<std::sync::Mutex<Option<T>>> =
            items.into_iter().map(|x| std::sync::Mutex::new(Some(x))).collect();
        let out: Vec<std::sync::Mutex<Option<R>>> =
            (0..n).map(|_| std::sync::Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..self.workers.min(n) {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        return;
                    }
                    let item = slots[i].lock().unwrap().take().unwrap();
                    *out[i].lock().unwrap() = Some(f(item));
                });
            }
        });
        out.into_iter()
            .map(|m| m.into_inner().unwrap().unwrap())
            .collect()
    }
}

impl Default for ThreadPool {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_all() {
        let pool = ThreadPool::with_workers(4);
        let mut data = vec![0u32; 1003];
        pool.scoped_chunks(&mut data, 100, |_ci, c| {
            for v in c.iter_mut() {
                *v += 1;
            }
        });
        assert!(data.iter().all(|&v| v == 1));
    }

    #[test]
    fn chunk_indices_correct() {
        let pool = ThreadPool::with_workers(3);
        let mut data = vec![0usize; 250];
        pool.scoped_chunks(&mut data, 100, |ci, c| {
            for v in c.iter_mut() {
                *v = ci;
            }
        });
        assert_eq!(data[0], 0);
        assert_eq!(data[150], 1);
        assert_eq!(data[249], 2);
    }

    #[test]
    fn for_ranges_covers() {
        let pool = ThreadPool::with_workers(4);
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        pool.for_ranges(97, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::with_workers(4);
        let out = pool.map((0..100).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_fallback() {
        let pool = ThreadPool::with_workers(1);
        let mut data = vec![1u8; 10];
        pool.scoped_chunks(&mut data, 3, |_, c| {
            for v in c.iter_mut() {
                *v = 2;
            }
        });
        assert!(data.iter().all(|&v| v == 2));
    }
}
