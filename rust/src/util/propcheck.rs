//! Minimal property-based testing harness (proptest stand-in).
//!
//! Seeded generation + greedy shrinking over a recorded `Vec<u64>` draw
//! tape. A property takes a [`Gen`] that draws values; on failure the
//! harness shrinks the tape (halving entries, truncating) and panics with
//! the smallest failing tape it found.
//!
//! Usage:
//! ```ignore
//! propcheck::check(200, |g| {
//!     let n = g.usize_in(1, 64);
//!     let xs = g.vec_u32(n, 0, 1000);
//!     prop_assert(invariant(&xs), "invariant")
//! });
//! ```

use super::rng::Pcg64;

/// Value source for properties. Reads from a replay tape first; once the
/// tape is exhausted, draws from a seeded RNG. Every draw is recorded so
/// the harness can shrink the exact sequence that failed.
pub struct Gen {
    tape: Vec<u64>,
    cursor: usize,
    rng: Pcg64,
    record: Vec<u64>,
}

impl Gen {
    fn new(tape: Vec<u64>, seed: u64) -> Self {
        Gen {
            tape,
            cursor: 0,
            rng: Pcg64::seeded(seed),
            record: Vec::new(),
        }
    }

    #[inline]
    fn draw(&mut self) -> u64 {
        let v = if self.cursor < self.tape.len() {
            let v = self.tape[self.cursor];
            self.cursor += 1;
            v
        } else {
            self.rng.next_u64()
        };
        self.record.push(v);
        v
    }

    pub fn u64(&mut self) -> u64 {
        self.draw()
    }

    /// Inclusive-bounds usize.
    pub fn usize_in(&mut self, lo: usize, hi_incl: usize) -> usize {
        assert!(hi_incl >= lo);
        lo + (self.draw() % (hi_incl - lo + 1) as u64) as usize
    }

    /// Inclusive-bounds u32.
    pub fn u32_in(&mut self, lo: u32, hi_incl: u32) -> u32 {
        lo + (self.draw() % (hi_incl - lo + 1) as u64) as u32
    }

    pub fn f64_unit(&mut self) -> f64 {
        (self.draw() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn bool(&mut self) -> bool {
        self.draw() & 1 == 1
    }

    pub fn vec_u32(&mut self, len: usize, lo: u32, hi_incl: u32) -> Vec<u32> {
        (0..len).map(|_| self.u32_in(lo, hi_incl)).collect()
    }

    pub fn vec_f32_unit(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.f64_unit() as f32).collect()
    }

    /// Distinct sorted u32 indices in [0, bound).
    pub fn distinct_sorted_u32(&mut self, len: usize, bound: u32) -> Vec<u32> {
        assert!(len as u64 <= bound as u64);
        let mut set = std::collections::BTreeSet::new();
        // Bounded loop: when len is close to bound, fill deterministically.
        if len * 2 >= bound as usize {
            let mut all: Vec<u32> = (0..bound).collect();
            // Draw-based partial shuffle for determinism under replay.
            for i in 0..len {
                let j = i + (self.draw() % (bound as u64 - i as u64)) as usize;
                all.swap(i, j);
            }
            let mut v = all[..len].to_vec();
            v.sort_unstable();
            return v;
        }
        while set.len() < len {
            set.insert(self.u32_in(0, bound - 1));
        }
        set.into_iter().collect()
    }
}

/// Property outcome.
pub type PropResult = Result<(), String>;

/// Assert helper for property bodies.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Run `cases` random cases of `prop`. Panics with the shrunk
/// counterexample on failure. Deterministic given `seed`.
pub fn check_seeded<F>(seed: u64, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    for case in 0..cases {
        let case_seed = seed
            .wrapping_add(case as u64)
            .wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut g = Gen::new(Vec::new(), case_seed);
        if let Err(msg) = prop(&mut g) {
            let tape = g.record.clone();
            let (tape, msg) = shrink(&prop, tape, msg, case_seed);
            panic!(
                "property failed (seed={case_seed}, case={case}): {msg}\n\
                 shrunk tape ({} draws, first 32 shown): {:?}",
                tape.len(),
                &tape[..tape.len().min(32)]
            );
        }
    }
}

/// Run with the default seed.
pub fn check<F>(cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    check_seeded(0x5eed_cafe, cases, prop)
}

fn shrink<F>(prop: &F, tape: Vec<u64>, msg: String, seed: u64) -> (Vec<u64>, String)
where
    F: Fn(&mut Gen) -> PropResult,
{
    let mut best = tape;
    let mut best_msg = msg;
    let mut budget = 300usize;
    let mut improved = true;
    while improved && budget > 0 {
        improved = false;
        let mut candidates: Vec<Vec<u64>> = Vec::new();
        if best.len() > 1 {
            candidates.push(best[..best.len() / 2].to_vec());
            candidates.push(best[..best.len() - 1].to_vec());
        }
        for i in 0..best.len().min(24) {
            if best[i] != 0 {
                let mut t = best.clone();
                t[i] /= 2;
                candidates.push(t);
                let mut t0 = best.clone();
                t0[i] = 0;
                candidates.push(t0);
            }
        }
        for cand in candidates {
            if budget == 0 {
                break;
            }
            budget -= 1;
            let mut g = Gen::new(cand.clone(), seed);
            if let Err(m) = prop(&mut g) {
                let smaller = cand.len() < best.len()
                    || (cand.len() == best.len()
                        && cand.iter().map(|v| *v as u128).sum::<u128>()
                            < best.iter().map(|v| *v as u128).sum::<u128>());
                if smaller {
                    best = cand;
                    best_msg = m;
                    improved = true;
                    break;
                }
            }
        }
    }
    (best, best_msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(50, |g| {
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            prop_assert(a + b >= a, "monotone add")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(50, |g| {
            let a = g.usize_in(0, 1000);
            prop_assert(a < 500, "a < 500")
        });
    }

    #[test]
    fn distinct_sorted_invariants() {
        check(50, |g| {
            let len = g.usize_in(0, 50);
            let v = g.distinct_sorted_u32(len, 1000);
            let sorted = v.windows(2).all(|w| w[0] < w[1]);
            prop_assert(sorted && v.len() == len, "sorted distinct")
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut g1 = Gen::new(Vec::new(), 99);
        let seq: Vec<u64> = (0..32).map(|_| g1.u64()).collect();
        let mut g2 = Gen::new(seq.clone(), 99);
        let replayed: Vec<u64> = (0..32).map(|_| g2.u64()).collect();
        assert_eq!(seq, replayed);
    }
}
