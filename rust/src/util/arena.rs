//! Scratch-arena substrate for the allocation-free hot path.
//!
//! The first perf passes showed the partition→encode→decode pipeline
//! spending more wall time in the allocator than in the algorithm: every
//! simulated iteration rebuilt the same `Vec`s from scratch. This module
//! supplies the two generic building blocks that fix it:
//!
//! - [`ScratchPool`] — a checkout pool of reusable scratch objects. A
//!   caller [`acquire`](ScratchPool::acquire)s one per concurrent unit of
//!   work (the engine: one per in-flight bucket sync), mutates it freely,
//!   and the guard returns it on drop. After warm-up the pool serves
//!   every checkout from recycled objects whose internal buffers have
//!   already grown to steady-state capacity — zero allocations per
//!   iteration.
//! - [`OnceMap`] — a fixed-capacity, insert-once map with **lock-free
//!   reads** (an `OnceLock` probe table). It replaces the
//!   `Mutex<HashMap>` that previously guarded Zen's partition-domain
//!   cache: domains are computed exactly once per key and every
//!   subsequent lookup is a handful of atomic loads, so concurrent
//!   bucket syncs never contend on a lock.
//!
//! Domain-specific scratch types build on these:
//! [`crate::hashing::hierarchical::PartitionScratch`],
//! [`crate::util::radix::RadixScratch`], and
//! [`crate::schemes::SyncScratch`].

use std::ops::{Deref, DerefMut};
use std::sync::{Mutex, OnceLock};

/// A checkout pool of reusable scratch objects.
///
/// `acquire()` pops a recycled object (or creates a fresh `T::default()`
/// when the pool is dry); the returned guard hands the object back on
/// drop. The pool never shrinks: steady-state acquire/release cycles
/// perform no allocation beyond what `T`'s own buffers do.
pub struct ScratchPool<T> {
    free: Mutex<Vec<T>>,
}

impl<T: Default> ScratchPool<T> {
    pub fn new() -> Self {
        ScratchPool {
            free: Mutex::new(Vec::new()),
        }
    }

    /// Check out one scratch object; it returns to the pool when the
    /// guard drops.
    pub fn acquire(&self) -> ScratchGuard<'_, T> {
        let item = self.free.lock().unwrap().pop().unwrap_or_default();
        ScratchGuard {
            pool: self,
            item: Some(item),
        }
    }

    /// Number of idle objects currently parked in the pool.
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

impl<T: Default> Default for ScratchPool<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// RAII checkout handle for a [`ScratchPool`] object.
pub struct ScratchGuard<'a, T: Default> {
    pool: &'a ScratchPool<T>,
    item: Option<T>,
}

impl<T: Default> Deref for ScratchGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.item.as_ref().expect("scratch present until drop")
    }
}

impl<T: Default> DerefMut for ScratchGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.item.as_mut().expect("scratch present until drop")
    }
}

impl<T: Default> Drop for ScratchGuard<'_, T> {
    fn drop(&mut self) {
        if let Some(item) = self.item.take() {
            self.pool.free.lock().unwrap().push(item);
        }
    }
}

/// A fixed-capacity insert-once map from `usize` keys to values, with
/// lock-free reads.
///
/// Implementation: an open-addressed probe table of
/// `OnceLock<(key, value)>` slots. A hit is a few atomic loads; a miss
/// runs the init closure under the slot's one-time initialization (so a
/// value is computed **exactly once per key**, even under racing
/// readers — `OnceLock` blocks the losers until the winner's value is
/// ready, and a loser's closure is never run). Entries are immutable and
/// never evicted; `get_or_init` returns `None` only when the table is
/// full of other keys, in which case the caller falls back to its own
/// slow path (e.g. Zen keeps a mutex-guarded overflow tier).
pub struct OnceMap<V> {
    slots: Box<[OnceLock<(usize, V)>]>,
}

impl<V> OnceMap<V> {
    /// A table with room for `capacity` distinct keys (min 1).
    pub fn with_capacity(capacity: usize) -> Self {
        OnceMap {
            slots: (0..capacity.max(1)).map(|_| OnceLock::new()).collect(),
        }
    }

    /// Look up `key`, initializing it with `init` on first touch.
    /// Returns `None` iff the table is full of other keys.
    pub fn get_or_init<F: FnOnce() -> V>(&self, key: usize, init: F) -> Option<&V> {
        let cap = self.slots.len();
        // Fibonacci-hash start slot; linear probe from there.
        let start = (key.wrapping_mul(0x9e37_79b9_7f4a_7c15) >> 7) % cap;
        let mut init = Some(init);
        for i in 0..cap {
            let slot = &self.slots[(start + i) % cap];
            let entry = slot.get_or_init(|| {
                let f = init.take().expect("init consumed only when run");
                (key, f())
            });
            if entry.0 == key {
                return Some(&entry.1);
            }
        }
        None
    }

    /// Lock-free read-only lookup.
    pub fn get(&self, key: usize) -> Option<&V> {
        let cap = self.slots.len();
        let start = (key.wrapping_mul(0x9e37_79b9_7f4a_7c15) >> 7) % cap;
        for i in 0..cap {
            match self.slots[(start + i) % cap].get() {
                Some((k, v)) if *k == key => return Some(v),
                Some(_) => continue,
                None => return None,
            }
        }
        None
    }

    /// Number of occupied slots.
    pub fn len(&self) -> usize {
        self.slots.iter().filter(|s| s.get().is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_recycles_objects() {
        let pool: ScratchPool<Vec<u32>> = ScratchPool::new();
        {
            let mut a = pool.acquire();
            a.extend_from_slice(&[1, 2, 3]);
        } // returned with capacity ≥ 3
        assert_eq!(pool.idle(), 1);
        let b = pool.acquire();
        assert!(b.capacity() >= 3, "recycled object keeps its capacity");
        assert_eq!(pool.idle(), 0);
    }

    #[test]
    fn pool_grows_under_concurrent_checkout() {
        let pool: ScratchPool<Vec<u8>> = ScratchPool::new();
        let a = pool.acquire();
        let b = pool.acquire();
        drop(a);
        drop(b);
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn once_map_initializes_exactly_once() {
        let map: OnceMap<u64> = OnceMap::with_capacity(8);
        let computes = AtomicUsize::new(0);
        for _ in 0..5 {
            let v = map
                .get_or_init(42, || {
                    computes.fetch_add(1, Ordering::Relaxed);
                    4200
                })
                .unwrap();
            assert_eq!(*v, 4200);
        }
        assert_eq!(computes.load(Ordering::Relaxed), 1);
        assert_eq!(map.get(42), Some(&4200));
        assert_eq!(map.get(43), None);
    }

    #[test]
    fn once_map_distinct_keys_coexist() {
        let map: OnceMap<usize> = OnceMap::with_capacity(16);
        for k in 0..16 {
            assert_eq!(map.get_or_init(k * 1000, || k), Some(&k));
        }
        for k in 0..16 {
            assert_eq!(map.get(k * 1000), Some(&k));
        }
        assert_eq!(map.len(), 16);
        // 17th distinct key: table full → caller falls back
        assert_eq!(map.get_or_init(99_999, || 99), None);
    }

    #[test]
    fn once_map_exactly_once_under_racing_threads() {
        let map: OnceMap<usize> = OnceMap::with_capacity(4);
        static COMPUTES: AtomicUsize = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let v = map
                        .get_or_init(7, || {
                            COMPUTES.fetch_add(1, Ordering::Relaxed);
                            777
                        })
                        .unwrap();
                    assert_eq!(*v, 777);
                });
            }
        });
        assert_eq!(COMPUTES.load(Ordering::Relaxed), 1);
    }
}
