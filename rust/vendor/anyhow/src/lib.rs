//! Std-only stand-in for the `anyhow` crate, vendored because the offline
//! crate registry carries no crates.io closure.
//!
//! Implements exactly the subset the `zen` crate uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the
//! [`Context`] extension trait for `Result` and `Option`. The coherence
//! trick that lets `.context()` work both on `Result<_, E: std::error::Error>`
//! and on `Result<_, anyhow::Error>` mirrors upstream anyhow: [`Error`]
//! deliberately does *not* implement `std::error::Error`, and a private
//! helper trait is implemented for both families.

use std::fmt;

/// A type-erased error: a message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from anything printable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Construct from a concrete error, keeping it as the source.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Self {
        Error {
            msg: error.to_string(),
            source: Some(Box::new(error)),
        }
    }

    /// Wrap with higher-level context (rendered as `context: cause`).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: format!("{context}: {}", self.msg),
            source: self.source,
        }
    }

    /// The root cause, if a concrete source error was preserved.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source
            .as_deref()
            .map(|e| e as &(dyn std::error::Error + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> anyhow::Result<()>` prints through Debug; make it
        // read like a message, with the source chain appended.
        write!(f, "{}", self.msg)?;
        let mut cause = self.source();
        while let Some(c) = cause {
            write!(f, "\n\ncaused by: {c}")?;
            cause = c.source();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

mod private {
    /// Sealed conversion into [`super::Error`], implemented for every
    /// `std::error::Error` and for `Error` itself. The two impls do not
    /// overlap because `Error` never implements `std::error::Error`.
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> super::Error {
            super::Error::new(self)
        }
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(|| ...)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: private::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_io() -> Result<u32> {
        let v = "12x".parse::<u32>()?; // std error converts via `?`
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = parse_io().unwrap_err();
        assert!(e.to_string().contains("invalid digit"));
        assert!(e.source().is_some());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("while rendering").unwrap_err();
        assert!(e.to_string().starts_with("while rendering: "));

        let none: Option<u32> = None;
        let e = none.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn context_chains_on_anyhow_error() {
        let base: Result<()> = Err(anyhow!("inner {}", 7));
        let e = base.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 7");
    }

    #[test]
    fn ensure_and_bail() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(check(2).unwrap(), 2);
        assert_eq!(check(3).unwrap_err().to_string(), "unlucky 3");
        assert_eq!(check(12).unwrap_err().to_string(), "too big: 12");
    }

    #[test]
    fn debug_renders_chain() {
        let e = parse_io().unwrap_err().context("loading config");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("loading config"));
        assert!(dbg.contains("caused by"));
    }
}
