//! Bench: the pipelined multi-tensor sync engine — serialized vs
//! overlapped iteration time for Zen and DenseAllReduce on the LSTM and
//! BERT profiles at 16 machines, so the speedup of overlap × bucketing ×
//! scheme choice is directly readable from one run.
//!
//!   cargo bench --bench bench_engine

use zen::cluster::{LinkKind, Network};
use zen::coordinator::compute_time_per_iter;
use zen::engine::{EngineConfig, SyncEngine};
use zen::planner::FixedPlanner;
use zen::schemes::{self, SyncScheme};
use zen::util::human_bytes;
use zen::util::timer::bench;
use zen::workload::{profiles, GradientGen};

fn main() {
    let machines = 16;
    let net = Network::new(machines, LinkKind::Tcp25);
    for model in ["LSTM", "BERT"] {
        let profile = profiles::by_name(model).unwrap().scaled(256);
        let gen = GradientGen::new(profile, 0xeb);
        let specs = gen.layer_specs(4, 8);
        let layers = gen.layer_iteration_all(&specs, 0, machines);
        let compute = compute_time_per_iter(model);
        let engine = SyncEngine::new(EngineConfig::new(256 * 1024, compute));
        println!(
            "== {model} @ {machines} machines: {} layers, compute {:.0}ms ==",
            specs.len(),
            compute * 1e3
        );
        for scheme_name in ["zen", "allreduce"] {
            let planner = FixedPlanner::new(
                schemes::by_name(scheme_name, machines, 0x5eed, gen.expected_nnz().max(64))
                    .unwrap(),
            );
            let run = engine.run(&specs, &layers, &planner, &net, |r| r.comm_time());
            println!(
                "{model} {:<10} serialized {:>8.2} ms   overlapped {:>8.2} ms   \
                 speedup {:.2}x   ({} buckets, {} on the wire)",
                planner.scheme().name(),
                run.serialized_time * 1e3,
                run.overlapped_time * 1e3,
                run.speedup(),
                run.buckets.len(),
                human_bytes(run.total_bytes as f64)
            );
            assert!(
                run.overlapped_time < run.serialized_time,
                "{model}/{scheme_name}: overlap must strictly beat the serialized \
                 schedule ({} vs {})",
                run.overlapped_time,
                run.serialized_time
            );
            bench(&format!("engine {model} {scheme_name}"), 1, 5, || {
                std::hint::black_box(engine.run(&specs, &layers, &planner, &net, |r| {
                    r.comm_time()
                }));
            });
        }
        println!();
    }
}
