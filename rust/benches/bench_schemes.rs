//! Bench: every synchronization scheme on every Table-1 workload at 16
//! machines — wall time of the scheme implementation plus the virtual
//! network time it charges (Fig 13's quantities).
//!
//!   cargo bench --bench bench_schemes

use zen::cluster::{LinkKind, Network};
use zen::schemes::{self, SyncScheme};
use zen::util::timer::bench;
use zen::workload::{profiles, GradientGen};

fn main() {
    let n = 16;
    let net = Network::new(n, LinkKind::Tcp25);
    for p in profiles::table1() {
        let gen = GradientGen::new(p.scaled(256), 0xbe);
        let inputs = gen.iteration_all(0, n);
        println!(
            "== {} (scaled): {} params, nnz/worker {} ==",
            p.name,
            inputs[0].dense_len,
            inputs[0].nnz()
        );
        let mut dense_time = 0.0;
        let mut scratch = schemes::SyncScratch::new();
        for scheme in schemes::all_schemes(n, 5, inputs[0].nnz()) {
            let r = scheme.run_sim(&inputs, &net, &mut scratch);
            let virt = r.report.comm_time();
            if scheme.name() == "AllReduce" {
                dense_time = virt;
            }
            bench(
                &format!(
                    "{:<11} virt {:.2}ms speedup {:.2}x",
                    scheme.name(),
                    virt * 1e3,
                    dense_time / virt
                ),
                1,
                5,
                || {
                    std::hint::black_box(scheme.run_sim(
                        &inputs,
                        &net,
                        &mut schemes::SyncScratch::new(),
                    ));
                },
            );
        }
        println!();
    }
}
