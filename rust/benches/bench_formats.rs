//! Bench: sparse wire formats (Fig 17's quantities) — encode/decode
//! throughput and wire size for COO, bitmap, tensor block, hash bitmap.
//!
//!   cargo bench --bench bench_formats

use zen::hashing::{HashBitmapCodec, HierarchicalHasher};
use zen::tensor::{Bitmap, BlockTensor, CooTensor, WireFormat};
use zen::util::timer::bench;
use zen::util::{human_bytes, Pcg64};

fn main() {
    let dense_len = 1 << 22; // 4M params
    let hasher = HierarchicalHasher::with_defaults(3, 16, dense_len / 20);
    let domains = hasher.partition_domains(dense_len);

    for density_pct in [1.0f64, 10.0, 40.0] {
        let nnz = (density_pct / 100.0 * dense_len as f64) as usize;
        let mut rng = Pcg64::seeded(9);
        let mut idx: Vec<u32> = rng
            .sample_distinct(dense_len, nnz)
            .into_iter()
            .map(|i| i as u32)
            .collect();
        idx.sort_unstable();
        let t = CooTensor::from_sorted(dense_len, idx, vec![1.0; nnz]);
        println!(
            "== density {density_pct}% ({nnz} nnz, dense {}) ==",
            human_bytes((dense_len * 4) as f64)
        );
        println!("  wire: COO {}", human_bytes(t.wire_bytes() as f64));
        let bm = Bitmap::from_ones(dense_len, &t.indices);
        println!(
            "  wire: bitmap+vals {}",
            human_bytes((bm.wire_bytes() + nnz * 4) as f64)
        );
        let blocks = BlockTensor::from_coo(&t, 256);
        println!("  wire: blocks {}", human_bytes(blocks.wire_bytes() as f64));
        let parts = hasher.partition(&t).parts;
        let hb_total: usize = parts
            .iter()
            .enumerate()
            .map(|(p, part)| {
                HashBitmapCodec::new(&domains[p])
                    .encode(part)
                    .wire_bytes()
            })
            .sum();
        println!("  wire: hash bitmap {}", human_bytes(hb_total as f64));

        bench("block encode", 1, 5, || {
            std::hint::black_box(BlockTensor::from_coo(&t, 256));
        });
        bench("bitmap encode", 1, 5, || {
            std::hint::black_box(Bitmap::from_ones(dense_len, &t.indices));
        });
        let codec = HashBitmapCodec::new(&domains[0]);
        let payload = codec.encode(&parts[0]);
        bench("hash-bitmap encode (1 partition)", 1, 5, || {
            std::hint::black_box(codec.encode(&parts[0]));
        });
        bench("hash-bitmap decode (1 partition)", 1, 5, || {
            std::hint::black_box(codec.decode(&payload, dense_len));
        });
        println!();
    }
}
