//! Bench: Algorithm 1 (hierarchical hashing) hot path — regenerates the
//! Fig 16 parameter study and the Fig 8 strawman trade-off, and reports
//! the hashing throughput target from DESIGN.md §Perf.
//!
//!   cargo bench --bench bench_hashing

use zen::hashing::{HierarchicalHasher, StrawmanHasher, ThresholdPartitioner};
use zen::tensor::CooTensor;
use zen::util::timer::bench;
use zen::util::Pcg64;

fn random_coo(seed: u64, dense_len: usize, nnz: usize) -> CooTensor {
    let mut rng = Pcg64::seeded(seed);
    let mut idx = rng.sample_distinct(dense_len, nnz);
    idx.sort_unstable();
    CooTensor::from_sorted(
        dense_len,
        idx.into_iter().map(|i| i as u32).collect(),
        (0..nnz).map(|_| rng.next_f32() + 0.01).collect(),
    )
}

fn main() {
    println!("== Algorithm 1: throughput vs tensor size (n=16, k=3, r1=2nnz) ==");
    for nnz in [10_000usize, 100_000, 1_000_000] {
        let t = random_coo(1, nnz * 40, nnz);
        let h = HierarchicalHasher::with_defaults(7, 16, nnz);
        let s = bench(&format!("alg1 nnz={nnz}"), 2, 8, || {
            std::hint::black_box(h.partition(&t));
        });
        let mut s = s;
        println!(
            "  -> {:.1} M idx/s",
            nnz as f64 / s.percentile(50.0) / 1e6
        );
    }

    println!("\n== Fig 16a analog: cost vs r1 multiple (nnz=500k, k=3) ==");
    let t = random_coo(2, 20_000_000, 500_000);
    for mult in [1usize, 2, 4, 8] {
        let r1 = mult * t.nnz() / 16;
        let h = HierarchicalHasher::new(7, 16, 3, r1, (r1 / 10).max(1));
        let out = h.partition(&t);
        bench(
            &format!(
                "alg1 r1={mult}x (serial={}, overflow={})",
                out.serial_writes, out.overflow_writes
            ),
            1,
            5,
            || {
                std::hint::black_box(h.partition(&t));
            },
        );
    }

    println!("\n== Fig 16b analog: cost vs k (r1=2nnz) ==");
    for k in [1usize, 2, 3, 4] {
        let r1 = 2 * t.nnz() / 16;
        let h = HierarchicalHasher::new(7, 16, k, r1, (r1 / 10).max(1));
        let out = h.partition(&t);
        bench(
            &format!("alg1 k={k} (serial={})", out.serial_writes),
            1,
            5,
            || {
                std::hint::black_box(h.partition(&t));
            },
        );
    }

    println!("\n== Fig 8 analog: strawman memory vs cost & loss ==");
    for mult in [1usize, 2, 8, 32] {
        let h = StrawmanHasher::new(5, 16, mult * t.nnz());
        let out = h.partition(&t);
        bench(
            &format!(
                "strawman mem={mult}x (loss {:.1}%)",
                out.loss_rate(t.nnz()) * 100.0
            ),
            1,
            5,
            || {
                std::hint::black_box(h.partition(&t));
            },
        );
    }

    println!("\n== data-dependent thresholds (fit cost) ==");
    bench("threshold fit nnz=500k", 1, 5, || {
        std::hint::black_box(ThresholdPartitioner::fit(&t.indices, 16));
    });
}
