//! Bench: end-to-end — simulated training throughput per scheme
//! (Fig 11's quantities) and, when artifacts exist, real steps/s of the
//! AOT-compiled trainer.
//!
//!   cargo bench --bench bench_e2e

use zen::cluster::LinkKind;
use zen::coordinator::lm::{LmConfig, LmTrainer};
use zen::coordinator::{SimConfig, SimDriver};
use zen::util::timer::bench;
use zen::workload::profiles;

fn main() {
    println!("== simulated throughput, DeepFM, 16 machines, 25Gbps ==");
    for scheme in ["allreduce", "sparcml", "omnireduce", "sparseps", "zen"] {
        let mut cfg = SimConfig::new(profiles::by_name("DeepFM").unwrap(), 16, scheme);
        cfg.scale = 256;
        cfg.iterations = 2;
        let driver = SimDriver::new(cfg).unwrap();
        let r = driver.run();
        bench(
            &format!("sim {:<11} {:>8.0} samples/s", r.scheme, r.throughput),
            0,
            3,
            || {
                std::hint::black_box(driver.run());
            },
        );
    }

    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("MANIFEST.txt").exists() {
        println!("\n(skipping real-trainer bench: run `make artifacts`)");
        return;
    }
    println!("\n== real trainer (tiny shape, 4 workers) steps/s ==");
    for scheme in ["allreduce", "zen"] {
        let mut t =
            LmTrainer::new(LmConfig::tiny(), 4, scheme, LinkKind::Tcp25, &artifacts).unwrap();
        // warm the executable
        t.step().unwrap();
        let mut s = bench(&format!("train step ({scheme})"), 1, 10, || {
            std::hint::black_box(t.step().unwrap());
        });
        println!("  -> {:.1} steps/s", 1.0 / s.percentile(50.0));
    }
}
