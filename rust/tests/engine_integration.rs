//! Engine edge cases: empty-layer tensors (nnz = 0), a single bucket
//! holding the whole model, a bucket threshold smaller than one layer,
//! and the 1-machine topology — every case asserting the per-layer
//! outputs match `schemes::reference_sum` exactly.

use zen::cluster::{LinkKind, Network};
use zen::engine::{verify_layer_outputs, EngineConfig, SyncEngine};
use zen::planner::FixedPlanner;
use zen::schemes::{self, reference_sum};
use zen::tensor::CooTensor;
use zen::util::Pcg64;
use zen::workload::{LayerKind, LayerSpec};

fn fixed(name: &str, machines: usize, seed: u64, expected_nnz: usize) -> FixedPlanner {
    FixedPlanner::new(schemes::by_name(name, machines, seed, expected_nnz).unwrap())
}

fn spec(name: &str, params: usize, frac: f64) -> LayerSpec {
    LayerSpec {
        name: name.into(),
        params,
        kind: LayerKind::Dense,
        ready_frac: frac,
        fwd_order: 0,
    }
}

/// Hand-built model: 4 layers of varying size, random sparse tensors.
fn random_layers(seed: u64, machines: usize, specs: &[LayerSpec]) -> Vec<Vec<CooTensor>> {
    let mut rng = Pcg64::seeded(seed);
    (0..machines)
        .map(|_| {
            specs
                .iter()
                .map(|s| {
                    if s.params == 0 {
                        return CooTensor::empty(0);
                    }
                    let nnz = rng.below(s.params as u64 + 1) as usize;
                    let mut idx = rng.sample_distinct(s.params, nnz);
                    idx.sort_unstable();
                    let vals: Vec<f32> = (0..nnz).map(|_| rng.next_f32() + 0.1).collect();
                    CooTensor::from_sorted(
                        s.params,
                        idx.into_iter().map(|i| i as u32).collect(),
                        vals,
                    )
                })
                .collect()
        })
        .collect()
}

fn engine(bucket_bytes: usize) -> SyncEngine {
    SyncEngine::new(EngineConfig::new(bucket_bytes, 0.05))
}

fn check_all_schemes(
    machines: usize,
    specs: &[LayerSpec],
    layers: &[Vec<CooTensor>],
    bucket_bytes: usize,
) {
    let net = Network::new(machines, LinkKind::Tcp25);
    let eng = engine(bucket_bytes);
    for name in ["zen", "allreduce", "sparcml", "sparseps", "omnireduce", "agsparse"] {
        let planner = fixed(name, machines, 0x11, 256);
        let run = eng.run(specs, layers, &planner, &net, |r| r.comm_time());
        verify_layer_outputs(&run, layers);
        // belt and braces: re-derive the reference here as well
        for (l, out) in run.layer_outputs.iter().enumerate() {
            let inputs: Vec<CooTensor> = layers.iter().map(|w| w[l].clone()).collect();
            assert_eq!(
                out.to_dense().values.len(),
                reference_sum(&inputs).values.len(),
                "{name}: layer {l} length"
            );
        }
    }
}

#[test]
fn empty_layer_tensors_sync_to_zero() {
    // Every machine contributes nnz = 0 for layer 1 (params > 0, all
    // gradients zero) and a zero-param layer 2.
    let specs = vec![
        spec("head", 300, 0.4),
        spec("frozen", 200, 0.7),
        spec("ghost", 0, 0.8),
        spec("tail", 100, 1.0),
    ];
    let machines = 4;
    let mut layers = random_layers(1, machines, &specs);
    for w in layers.iter_mut() {
        w[1] = CooTensor::empty(200);
    }
    check_all_schemes(machines, &specs, &layers, 512);
    // and explicitly: the frozen layer aggregates to all-zero
    let net = Network::new(machines, LinkKind::Tcp25);
    let planner = fixed("zen", machines, 0x11, 256);
    let run = engine(512).run(&specs, &layers, &planner, &net, |r| r.comm_time());
    assert_eq!(run.layer_outputs[1].nnz(), 0);
    assert_eq!(run.layer_outputs[2].dense_len, 0);
}

#[test]
fn single_bucket_holds_whole_model() {
    let specs = vec![spec("a", 256, 0.3), spec("b", 512, 0.6), spec("c", 128, 1.0)];
    let machines = 4;
    let layers = random_layers(2, machines, &specs);
    let net = Network::new(machines, LinkKind::Tcp25);
    let planner = fixed("zen", machines, 0x22, 512);
    let run = engine(usize::MAX).run(&specs, &layers, &planner, &net, |r| r.comm_time());
    assert_eq!(run.buckets.len(), 1, "one bucket for the whole model");
    verify_layer_outputs(&run, &layers);
    check_all_schemes(machines, &specs, &layers, usize::MAX);
}

#[test]
fn threshold_smaller_than_one_layer_degenerates_to_per_layer() {
    let specs = vec![spec("a", 400, 0.5), spec("b", 400, 1.0)];
    let machines = 3;
    let layers = random_layers(3, machines, &specs);
    let net = Network::new(machines, LinkKind::Tcp25);
    let planner = fixed("zen", machines, 0x33, 256);
    // 1-byte threshold: smaller than any layer's payload
    let run = engine(1).run(&specs, &layers, &planner, &net, |r| r.comm_time());
    assert_eq!(run.buckets.len(), specs.len(), "one bucket per layer");
    verify_layer_outputs(&run, &layers);
    check_all_schemes(machines, &specs, &layers, 1);
}

#[test]
fn priority_schedule_never_changes_synced_values() {
    // Priority scheduling (and tensor partitioning) reorder *when*
    // buckets transmit, never *what* they carry: layer outputs, bytes,
    // and serialized time must be identical with the flag on or off,
    // and the priority run's forward-finish must never be worse.
    let specs = vec![
        spec("emb", 2_000, 0.25),
        spec("mlp0", 900, 0.5),
        spec("mlp1", 900, 0.75),
        spec("head", 400, 1.0),
    ];
    let machines = 4;
    let layers = random_layers(7, machines, &specs);
    let net = Network::new(machines, LinkKind::Tcp25);
    let planner = fixed("zen", machines, 0x55, 512);

    let greedy = SyncEngine::new(EngineConfig::new(2_048, 0.05)).run(
        &specs,
        &layers,
        &planner,
        &net,
        |r| r.comm_time(),
    );
    let prio = SyncEngine::new(EngineConfig::new(2_048, 0.05).with_priority(true)).run(
        &specs,
        &layers,
        &planner,
        &net,
        |r| r.comm_time(),
    );

    verify_layer_outputs(&greedy, &layers);
    verify_layer_outputs(&prio, &layers);
    assert_eq!(greedy.layer_outputs.len(), prio.layer_outputs.len());
    for (l, (g, p)) in greedy
        .layer_outputs
        .iter()
        .zip(prio.layer_outputs.iter())
        .enumerate()
    {
        assert_eq!(g.indices, p.indices, "layer {l} indices");
        let gb: Vec<u32> = g.values.iter().map(|v| v.to_bits()).collect();
        let pb: Vec<u32> = p.values.iter().map(|v| v.to_bits()).collect();
        assert_eq!(gb, pb, "layer {l} values");
    }
    assert_eq!(greedy.total_bytes, prio.total_bytes, "bytes on the wire");
    assert!(
        (greedy.serialized_time - prio.serialized_time).abs() < 1e-9,
        "serialized time: greedy {} vs priority {}",
        greedy.serialized_time,
        prio.serialized_time
    );
    assert!(
        prio.forward_finish <= greedy.forward_finish + 1e-9,
        "priority forward-finish {} must not exceed greedy {}",
        prio.forward_finish,
        greedy.forward_finish
    );

    // Partitioning on top of priority still reproduces the exact same
    // aggregated values (timing/bytes may differ: each piece pays its
    // own wire framing).
    let split = SyncEngine::new(
        EngineConfig::new(2_048, 0.05)
            .with_priority(true)
            .with_partition_bytes(1_024),
    )
    .run(&specs, &layers, &planner, &net, |r| r.comm_time());
    verify_layer_outputs(&split, &layers);
    for (l, (g, s)) in greedy
        .layer_outputs
        .iter()
        .zip(split.layer_outputs.iter())
        .enumerate()
    {
        assert_eq!(g.indices, s.indices, "split layer {l} indices");
        let gb: Vec<u32> = g.values.iter().map(|v| v.to_bits()).collect();
        let sb: Vec<u32> = s.values.iter().map(|v| v.to_bits()).collect();
        assert_eq!(gb, sb, "split layer {l} values");
    }
}

#[test]
fn one_machine_topology_is_exact_and_free() {
    let specs = vec![spec("a", 300, 0.5), spec("b", 100, 1.0)];
    let layers = random_layers(4, 1, &specs);
    let net = Network::new(1, LinkKind::Tcp25);
    let planner = fixed("zen", 1, 0x44, 128);
    let run = engine(1024).run(&specs, &layers, &planner, &net, |r| r.comm_time());
    verify_layer_outputs(&run, &layers);
    assert_eq!(run.total_bytes, 0, "nothing crosses the network");
    check_all_schemes(1, &specs, &layers, 1024);
}
