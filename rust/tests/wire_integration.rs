//! Wire integration: the real message fabric and the transport-observed
//! scheme accounting must agree — same aggregation result and, now that
//! every scheme charges framed bytes, *exactly* the same byte counts.
//! Plus the fabric-level satellites: concurrent interleaved frames with
//! exact counters, and `Disconnected` error coverage.

use zen::cluster::{LinkKind, Network};
use zen::hashing::HierarchicalHasher;
use zen::schemes::{self, SyncScheme, SyncScratch};
use zen::tensor::CooTensor;
use zen::wire::{Encode, Fabric, Message, WireError};
use zen::workload::{profiles, GradientGen};

fn inputs(n: usize) -> Vec<CooTensor> {
    GradientGen::new(profiles::by_name("NMT").unwrap().scaled(1024), 0xfab).iteration_all(0, n)
}

#[test]
fn fabric_aggregation_matches_analytic_scheme() {
    let n = 4;
    let ins = inputs(n);
    let nnz = ins[0].nnz();
    // orchestrated scheme (sim transport)
    let zen_scheme = schemes::by_name("zen", n, 0x1234, nnz).unwrap();
    let net = Network::new(n, LinkKind::Tcp25);
    let analytic = zen_scheme.run_sim(&ins, &net, &mut SyncScratch::new());
    // real fabric, one thread per endpoint, same hash family seed
    let hasher = HierarchicalHasher::with_defaults(0x1234, n, nnz);
    let (_fabric, eps) = Fabric::new(n);
    let real = Fabric::execute_zen_push_pull(eps, ins.clone(), &hasher);
    let reference = schemes::reference_sum(&ins);
    for out in real.iter().chain(analytic.outputs.iter()) {
        let dense = out.to_dense();
        for i in 0..dense.len() {
            let (a, b) = (dense.values[i], reference.values[i]);
            assert!((a - b).abs() <= 1e-5_f32.max(b.abs() * 1e-5), "idx {i}");
        }
    }
}

#[test]
fn fabric_bytes_match_scheme_accounting_exactly() {
    // Byte accounting now has one source of truth: the frames. The
    // threaded fabric deployment and the transport-driven scheme must
    // therefore agree byte-for-byte, not merely up to framing.
    let n = 4;
    let ins = inputs(n);
    let nnz = ins[0].nnz();
    let seed = 0x77aa;

    let mut zen_scheme = schemes::Zen::new(seed, n, nnz, schemes::ZenIndexFormat::HashBitmap);
    zen_scheme.charge_compute = false;
    let net = Network::new(n, LinkKind::Tcp25);
    let scheme_bytes = zen_scheme.run_sim(&ins, &net, &mut SyncScratch::new()).report.total_bytes();

    let hasher = HierarchicalHasher::with_defaults(seed, n, nnz);
    let (fabric, eps) = Fabric::new(n);
    let _ = Fabric::execute_zen_push_pull(eps, ins.clone(), &hasher);
    assert_eq!(fabric.total_bytes(), scheme_bytes);
}

#[test]
fn fabric_per_endpoint_balance() {
    // The real fabric's per-endpoint receive counters show Zen's balance.
    let n = 8;
    let ins = inputs(n);
    let hasher = HierarchicalHasher::with_defaults(9, n, ins[0].nnz());
    let (fabric, eps) = Fabric::new(n);
    let _ = Fabric::execute_zen_push_pull(eps, ins, &hasher);
    let recv: Vec<u64> = (0..n).map(|e| fabric.recv_bytes(e)).collect();
    let total: u64 = recv.iter().sum();
    let max = *recv.iter().max().unwrap();
    let imbalance = max as f64 * n as f64 / total as f64;
    assert!(imbalance < 1.15, "real-fabric receive imbalance {imbalance}");
}

#[test]
fn fabric_concurrent_interleaved_frames_counters_exact() {
    // N endpoint threads, each interleaving sends of differently-sized
    // frames to every peer with receives of (n−1)·k frames. The shared
    // counters must come out exact and symmetric — no lost or
    // double-counted bytes under concurrency.
    let n = 6;
    let rounds = 25;
    // endpoint e ships tensors with e+1 non-zeros → per-sender frame size
    let frame_len = |e: usize| -> u64 {
        Message::PushCoo {
            from: e as u32,
            tensor: CooTensor::from_sorted(
                64,
                (0..=e as u32).collect(),
                vec![1.0; e + 1],
            ),
        }
        .encoded_len() as u64
    };
    let (fabric, eps) = Fabric::new(n);
    std::thread::scope(|s| {
        for ep in eps {
            s.spawn(move || {
                let me = ep.id;
                let msg = Message::PushCoo {
                    from: me as u32,
                    tensor: CooTensor::from_sorted(
                        64,
                        (0..=me as u32).collect(),
                        vec![1.0; me + 1],
                    ),
                };
                let mut received = 0usize;
                for _ in 0..rounds {
                    for dst in 0..n {
                        if dst != me {
                            ep.send(dst, &msg).unwrap();
                        }
                        // interleave: drain anything already delivered
                        while let Some(m) = ep.try_recv().unwrap() {
                            assert!(matches!(m, Message::PushCoo { .. }));
                            received += 1;
                        }
                    }
                }
                while received < rounds * (n - 1) {
                    let m = ep.recv().unwrap();
                    assert!(matches!(m, Message::PushCoo { .. }));
                    received += 1;
                }
                // nothing extra may arrive beyond the expected count
                assert_eq!(received, rounds * (n - 1));
            });
        }
    });
    let mut total_sent = 0u64;
    let mut total_recv = 0u64;
    for e in 0..n {
        let expect_sent = rounds as u64 * (n as u64 - 1) * frame_len(e);
        let expect_recv: u64 = (0..n)
            .filter(|&o| o != e)
            .map(|o| rounds as u64 * frame_len(o))
            .sum();
        assert_eq!(fabric.sent_bytes(e), expect_sent, "sent[{e}]");
        assert_eq!(fabric.recv_bytes(e), expect_recv, "recv[{e}]");
        total_sent += fabric.sent_bytes(e);
        total_recv += fabric.recv_bytes(e);
    }
    assert_eq!(total_sent, total_recv, "fabric totals must be symmetric");
    assert_eq!(fabric.total_bytes(), total_sent);
}

#[test]
fn disconnection_maps_to_disconnected_error() {
    // Send side: the receiving endpoint is dropped.
    let (_fabric, mut eps) = Fabric::new(3);
    let victim = eps.remove(2);
    drop(victim);
    let err = eps[0]
        .send(2, &Message::Barrier { epoch: 1 })
        .expect_err("send to a hung-up peer must fail");
    assert_eq!(err, WireError::Disconnected);
    assert_eq!(err.to_string(), "peer endpoint disconnected");
    assert!(std::error::Error::source(&err).is_none());

    // Recv side: every sender to an inbox is gone.
    let (_fabric, mut eps) = Fabric::new(2);
    for ep in eps.iter_mut() {
        ep.disconnect();
    }
    assert_eq!(eps[0].recv(), Err(WireError::Disconnected));
    assert_eq!(eps[1].try_recv(), Err(WireError::Disconnected));
}
