//! Wire integration: the real message fabric and the analytic scheme
//! accounting must agree — same aggregation result, and the real
//! encoded byte counts match the simulator's charges up to the fixed
//! per-message framing overhead.

use zen::cluster::{LinkKind, Network};
use zen::hashing::HierarchicalHasher;
use zen::schemes::{self, SyncScheme};
use zen::wire::codec::FRAME_HEADER;
use zen::wire::Fabric;
use zen::workload::{profiles, GradientGen};

fn inputs(n: usize) -> Vec<zen::tensor::CooTensor> {
    GradientGen::new(profiles::by_name("NMT").unwrap().scaled(1024), 0xfab).iteration_all(0, n)
}

#[test]
fn fabric_aggregation_matches_analytic_scheme() {
    let n = 4;
    let ins = inputs(n);
    let nnz = ins[0].nnz();
    // analytic
    let zen_scheme = schemes::by_name("zen", n, 0x1234, nnz).unwrap();
    let net = Network::new(n, LinkKind::Tcp25);
    let analytic = zen_scheme.sync(&ins, &net);
    // real fabric, same hash family seed
    let hasher = HierarchicalHasher::with_defaults(0x1234 , n, nnz);
    let (_fabric, eps) = Fabric::new(n);
    let real = Fabric::execute_zen_push_pull(eps, ins.clone(), &hasher);
    let reference = schemes::reference_sum(&ins);
    for out in real.iter().chain(analytic.outputs.iter()) {
        let dense = out.to_dense();
        for i in 0..dense.len() {
            let (a, b) = (dense.values[i], reference.values[i]);
            assert!((a - b).abs() <= 1e-5_f32.max(b.abs() * 1e-5), "idx {i}");
        }
    }
}

#[test]
fn fabric_bytes_match_analytic_accounting_up_to_framing() {
    let n = 4;
    let ins = inputs(n);
    let nnz = ins[0].nnz();
    let seed = 0x77aa;

    // Analytic: Zen scheme push+pull byte totals (no compute charge).
    let mut zen_scheme = schemes::Zen::new(seed, n, nnz, schemes::ZenIndexFormat::HashBitmap);
    zen_scheme.charge_compute = false;
    let net = Network::new(n, LinkKind::Tcp25);
    let analytic_bytes = zen_scheme.sync(&ins, &net).report.total_bytes();

    // Real fabric with the same hasher.
    let hasher = HierarchicalHasher::with_defaults(seed, n, nnz);
    let (fabric, eps) = Fabric::new(n);
    let _ = Fabric::execute_zen_push_pull(eps, ins.clone(), &hasher);
    let real_bytes = fabric.total_bytes();

    // Per-message overhead: push = frame + from + dense_len + nnz;
    // pull = frame + server + domain_len + value-count. Bitmap word
    // padding (u64 words vs byte-exact accounting) adds ≤ 7 bytes per
    // pull message.
    let messages = (n * (n - 1) * 2) as u64;
    let per_msg_overhead = (FRAME_HEADER + 4 + 8 + 4) as u64;
    let lo = analytic_bytes;
    let hi = analytic_bytes + messages * (per_msg_overhead + 8);
    assert!(
        (lo..=hi).contains(&real_bytes),
        "real {real_bytes} outside [{lo}, {hi}]"
    );
}

#[test]
fn fabric_per_endpoint_balance() {
    // The real fabric's per-endpoint receive counters show Zen's balance.
    let n = 8;
    let ins = inputs(n);
    let hasher = HierarchicalHasher::with_defaults(9, n, ins[0].nnz());
    let (fabric, eps) = Fabric::new(n);
    let _ = Fabric::execute_zen_push_pull(eps, ins, &hasher);
    let recv: Vec<u64> = (0..n).map(|e| fabric.recv_bytes(e)).collect();
    let total: u64 = recv.iter().sum();
    let max = *recv.iter().max().unwrap();
    let imbalance = max as f64 * n as f64 / total as f64;
    assert!(imbalance < 1.15, "real-fabric receive imbalance {imbalance}");
}
