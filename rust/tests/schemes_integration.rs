//! Cross-module integration: every scheme against every model workload,
//! imbalance invariants, theorem orderings, and the Zen pipeline with
//! hash bitmaps end-to-end.

use zen::cluster::{LinkKind, Network};
use zen::schemes::{self, verify_outputs, SyncScheme, SyncScratch};
use zen::tensor::metrics;
use zen::workload::{profiles, GradientGen};

fn workload(model: &str, n: usize, iter: u64) -> Vec<zen::tensor::CooTensor> {
    GradientGen::new(profiles::by_name(model).unwrap().scaled(512), 0xabc).iteration_all(iter, n)
}

#[test]
fn every_scheme_correct_on_every_model() {
    for model in ["LSTM", "DeepFM", "NMT", "BERT"] {
        let inputs = workload(model, 6, 0);
        let net = Network::new(6, LinkKind::Tcp25);
        let nnz = inputs[0].nnz();
        for scheme in schemes::all_schemes(6, 3, nnz) {
            let r = scheme.run_sim(&inputs, &net, &mut SyncScratch::new());
            verify_outputs(&r, &inputs);
        }
    }
}

#[test]
fn every_scheme_correct_across_iterations() {
    // distributions drift across iterations; schemes must stay exact
    for iter in 0..3u64 {
        let inputs = workload("NMT", 4, iter);
        let net = Network::new(4, LinkKind::Rdma100);
        for scheme in schemes::all_schemes(4, iter, inputs[0].nnz()) {
            let r = scheme.run_sim(&inputs, &net, &mut SyncScratch::new());
            verify_outputs(&r, &inputs);
        }
    }
}

#[test]
fn zen_beats_baselines_on_comm_time() {
    // The headline claim, at simulation scale: Zen's virtual comm time
    // beats the sparse baselines on embedding workloads at n = 16.
    let inputs = workload("LSTM", 16, 0);
    let net = Network::new(16, LinkKind::Tcp25);
    let nnz = inputs[0].nnz();
    let time = |name: &str| {
        let s = schemes::by_name(name, 16, 5, nnz).unwrap();
        s.run_sim(&inputs, &net, &mut SyncScratch::new()).report.comm_time()
    };
    let zen_t = time("zen");
    for other in ["sparcml", "omnireduce", "sparseps", "agsparse"] {
        let t = time(other);
        assert!(zen_t < t, "zen ({zen_t:.6}s) should beat {other} ({t:.6}s)");
    }
}

#[test]
fn zen_imbalance_bounded_by_theorem2() {
    // Theorem 2 band: 1 + Θ(√(n log n / nnz)); allow 4× the Θ-constant.
    let inputs = workload("DeepFM", 8, 0);
    let net = Network::new(8, LinkKind::Tcp25);
    let nnz = inputs[0].nnz();
    let zen = schemes::by_name("zen", 8, 7, nnz).unwrap();
    let r = zen.run_sim(&inputs, &net, &mut SyncScratch::new());
    let push = r.report.stages[0].recv_imbalance();
    let bound = 1.0 + 4.0 * ((8.0 * (8f64).ln()) / nnz as f64).sqrt();
    assert!(push <= bound, "push imbalance {push} > theorem band {bound}");
}

#[test]
fn sparse_ps_imbalance_tracks_skewness() {
    // Definition 6: Sparse PS's push imbalance mirrors the skewness ratio.
    let inputs = workload("LSTM", 8, 0);
    let net = Network::new(8, LinkKind::Tcp25);
    let ps = schemes::by_name("sparseps", 8, 0, 0).unwrap();
    let r = ps.run_sim(&inputs, &net, &mut SyncScratch::new());
    let push_imb = r.report.stages[0].recv_imbalance();
    let skew: f64 = inputs
        .iter()
        .map(|t| metrics::skewness_ratio(t, 8))
        .sum::<f64>()
        / inputs.len() as f64;
    assert!(push_imb > 1.5, "push {push_imb}");
    assert!(skew > 1.5, "skew {skew}");
    let ratio = push_imb / skew;
    assert!((0.4..2.5).contains(&ratio), "push {push_imb} vs skew {skew}");
}

#[test]
fn dense_traffic_constant_zen_traffic_scales_with_density() {
    let sparse_in = workload("BERT", 4, 0);
    let net = Network::new(4, LinkKind::Tcp25);
    let dense = schemes::by_name("dense", 4, 0, 0).unwrap();
    let d1 = dense.run_sim(&sparse_in, &net, &mut SyncScratch::new()).report.total_bytes();
    // denser inputs → dense unchanged, zen grows
    let other = workload("BERT", 4, 1);
    let denser_in: Vec<zen::tensor::CooTensor> = sparse_in
        .iter()
        .zip(other.iter())
        .map(|(a, b)| a.merge(b))
        .collect();
    let d2 = dense.run_sim(&denser_in, &net, &mut SyncScratch::new()).report.total_bytes();
    assert_eq!(d1, d2);
    let zen = schemes::by_name("zen", 4, 3, sparse_in[0].nnz()).unwrap();
    let z1 = zen.run_sim(&sparse_in, &net, &mut SyncScratch::new()).report.total_bytes();
    let z2 = zen.run_sim(&denser_in, &net, &mut SyncScratch::new()).report.total_bytes();
    assert!(z2 as f64 > z1 as f64 * 1.4, "zen {z1} -> {z2}");
}

#[test]
fn strawman_loss_decreases_with_memory() {
    let inputs = workload("DeepFM", 4, 0);
    let net = Network::new(4, LinkKind::Tcp25);
    let nnz = inputs[0].nnz();
    let mut last_loss = f64::INFINITY;
    for mult in [1.0, 4.0, 16.0] {
        let s = zen::schemes::StrawmanScheme::new(9, 4, nnz, mult);
        let _ = s.run_sim(&inputs, &net, &mut SyncScratch::new());
        let loss = s.last_loss_rate();
        assert!(
            loss <= last_loss + 1e-9,
            "loss should fall with memory: {last_loss} -> {loss} at {mult}"
        );
        last_loss = loss;
    }
    assert!(last_loss < 0.05, "16× memory should be near-lossless");
}

#[test]
fn single_machine_all_schemes_trivial() {
    let inputs = workload("NMT", 1, 0);
    let net = Network::new(1, LinkKind::Tcp25);
    for scheme in schemes::all_schemes(1, 0, inputs[0].nnz()) {
        let r = scheme.run_sim(&inputs, &net, &mut SyncScratch::new());
        verify_outputs(&r, &inputs);
    }
}
