//! End-to-end training integration (needs `make artifacts`): the real
//! AOT-compiled train step under different synchronization schemes.
//!
//! Key invariant: since Zen is *lossless*, training under Zen must be
//! numerically indistinguishable from AllReduce (same loss trajectory),
//! while the lossy strawman diverges — the Fig 14 claim as a test.

use zen::cluster::LinkKind;
use zen::coordinator::lm::{LmConfig, LmTrainer};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("MANIFEST.txt").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn run_losses(scheme: &str, steps: usize) -> Vec<f32> {
    let mut cfg = LmConfig::tiny();
    cfg.seed = 0x7e57;
    let mut t = LmTrainer::new(cfg, 4, scheme, LinkKind::Tcp25, &artifacts_dir()).unwrap();
    t.run(steps, 0, false).unwrap().losses
}

#[test]
fn zen_matches_allreduce_loss_trajectory() {
    if !have_artifacts() {
        return;
    }
    let zen = run_losses("zen", 12);
    let dense = run_losses("allreduce", 12);
    for (i, (a, b)) in zen.iter().zip(dense.iter()).enumerate() {
        let tol = 1e-3_f32.max(b.abs() * 1e-3);
        assert!(
            (a - b).abs() < tol,
            "step {i}: zen {a} vs allreduce {b} — lossless schemes must agree"
        );
    }
}

#[test]
fn sparcml_and_omnireduce_also_match() {
    if !have_artifacts() {
        return;
    }
    let dense = run_losses("allreduce", 6);
    for scheme in ["sparcml", "omnireduce", "sparseps", "agsparse"] {
        let other = run_losses(scheme, 6);
        for (i, (a, b)) in other.iter().zip(dense.iter()).enumerate() {
            assert!(
                (a - b).abs() < 1e-3_f32.max(b.abs() * 1e-3),
                "{scheme} step {i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn lossy_strawman_diverges_from_allreduce() {
    if !have_artifacts() {
        return;
    }
    let dense = run_losses("allreduce", 12);
    let lossy = run_losses("strawman:1.2", 12);
    // the trajectories must measurably differ (gradients were dropped)
    let diverged = dense
        .iter()
        .zip(lossy.iter())
        .any(|(a, b)| (a - b).abs() > 1e-3_f32.max(a.abs() * 1e-3));
    assert!(diverged, "strawman with heavy loss should not match exactly");
}

#[test]
fn training_reduces_loss_and_improves_accuracy() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = LmConfig::tiny();
    cfg.seed = 0x900d;
    let mut t = LmTrainer::new(cfg, 4, "zen", LinkKind::Tcp25, &artifacts_dir()).unwrap();
    let acc0 = t.eval_accuracy(512);
    let log = t.run(60, 0, false).unwrap();
    let acc1 = t.eval_accuracy(512);
    let first = log.losses.first().copied().unwrap();
    let last = log.losses.last().copied().unwrap();
    assert!(last < first, "loss must fall: {first} -> {last}");
    assert!(acc1 > acc0 + 0.05, "accuracy must rise: {acc0} -> {acc1}");
}

#[test]
fn comm_time_zen_below_allreduce_at_scale() {
    if !have_artifacts() {
        return;
    }
    let mut mk = |scheme: &str| -> f64 {
        let mut cfg = LmConfig::tiny();
        cfg.seed = 0x5ca1e;
        let mut t =
            LmTrainer::new(cfg, 8, scheme, LinkKind::Tcp25, &artifacts_dir()).unwrap();
        t.step().unwrap().emb_comm_time
    };
    let zen = mk("zen");
    let dense = mk("allreduce");
    assert!(
        zen < dense,
        "zen emb comm {zen} should be below allreduce {dense}"
    );
}
