//! End-to-end training integration (needs `make artifacts`): the real
//! AOT-compiled train step under different synchronization schemes.
//!
//! Key invariant: since Zen is *lossless*, training under Zen must be
//! numerically indistinguishable from AllReduce (same loss trajectory),
//! while the lossy strawman diverges — the Fig 14 claim as a test.

use zen::cluster::LinkKind;
use zen::coordinator::lm::{LmConfig, LmTrainer};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("MANIFEST.txt").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn run_losses(scheme: &str, steps: usize) -> Vec<f32> {
    let mut cfg = LmConfig::tiny();
    cfg.seed = 0x7e57;
    let mut t = LmTrainer::new(cfg, 4, scheme, LinkKind::Tcp25, &artifacts_dir()).unwrap();
    t.run(steps, 0, false).unwrap().losses
}

#[test]
fn zen_matches_allreduce_loss_trajectory() {
    if !have_artifacts() {
        return;
    }
    let zen = run_losses("zen", 12);
    let dense = run_losses("allreduce", 12);
    for (i, (a, b)) in zen.iter().zip(dense.iter()).enumerate() {
        let tol = 1e-3_f32.max(b.abs() * 1e-3);
        assert!(
            (a - b).abs() < tol,
            "step {i}: zen {a} vs allreduce {b} — lossless schemes must agree"
        );
    }
}

#[test]
fn sparcml_and_omnireduce_also_match() {
    if !have_artifacts() {
        return;
    }
    let dense = run_losses("allreduce", 6);
    for scheme in ["sparcml", "omnireduce", "sparseps", "agsparse"] {
        let other = run_losses(scheme, 6);
        for (i, (a, b)) in other.iter().zip(dense.iter()).enumerate() {
            assert!(
                (a - b).abs() < 1e-3_f32.max(b.abs() * 1e-3),
                "{scheme} step {i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn lossy_strawman_diverges_from_allreduce() {
    if !have_artifacts() {
        return;
    }
    let dense = run_losses("allreduce", 12);
    let lossy = run_losses("strawman:1.2", 12);
    // the trajectories must measurably differ (gradients were dropped)
    let diverged = dense
        .iter()
        .zip(lossy.iter())
        .any(|(a, b)| (a - b).abs() > 1e-3_f32.max(a.abs() * 1e-3));
    assert!(diverged, "strawman with heavy loss should not match exactly");
}

#[test]
fn training_reduces_loss_and_improves_accuracy() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = LmConfig::tiny();
    cfg.seed = 0x900d;
    let mut t = LmTrainer::new(cfg, 4, "zen", LinkKind::Tcp25, &artifacts_dir()).unwrap();
    let acc0 = t.eval_accuracy(512);
    let log = t.run(60, 0, false).unwrap();
    let acc1 = t.eval_accuracy(512);
    let first = log.losses.first().copied().unwrap();
    let last = log.losses.last().copied().unwrap();
    assert!(last < first, "loss must fall: {first} -> {last}");
    assert!(acc1 > acc0 + 0.05, "accuracy must rise: {acc0} -> {acc1}");
}

#[test]
fn compressed_training_converges_within_budget_and_saves_bytes() {
    // PR 9 convergence regression: error-feedback Top-k under a fixed
    // scheme must still learn — final loss within the accuracy budget
    // of the lossless run on identical data — while shipping a fraction
    // of the wire bytes. The residuals carry what each step dropped,
    // so the trajectory differs but the destination must not.
    if !have_artifacts() {
        return;
    }
    let budget = 0.15f32;
    let steps = 60;
    let mk = |compress: zen::compress::CompressSpec| {
        let mut cfg = LmConfig::tiny();
        cfg.seed = 0xc0de;
        cfg.compress = compress;
        LmTrainer::builder(cfg)
            .scheme("zen")
            .workers(4, LinkKind::Tcp25)
            .artifacts_dir(&artifacts_dir())
            .build()
            .unwrap()
    };
    let base_log = mk(zen::compress::CompressSpec::None).run(steps, 0, false).unwrap();
    let mut lossy_t = mk(zen::compress::CompressSpec::TopK(0.05));
    let lossy_log = lossy_t.run(steps, 0, false).unwrap();
    let base_loss = base_log.losses.last().copied().unwrap();
    let lossy_loss = lossy_log.losses.last().copied().unwrap();
    assert!(
        lossy_loss < lossy_log.losses.first().copied().unwrap(),
        "compressed training must still reduce loss"
    );
    assert!(
        (lossy_loss - base_loss).abs() < budget,
        "top-k run drifted outside the accuracy budget: {lossy_loss} vs {base_loss}"
    );
    assert_eq!(lossy_log.lossy_steps, steps, "fixed scheme compresses every step");
    assert!(
        lossy_log.comm_bytes_total * 2 < base_log.comm_bytes_total,
        "top-k should at least halve wire bytes: {} vs {}",
        lossy_log.comm_bytes_total,
        base_log.comm_bytes_total
    );
    // The lossless run never compresses and accounts zero lossy steps.
    assert_eq!(base_log.lossy_steps, 0);
}

#[test]
fn comm_time_zen_below_allreduce_at_scale() {
    if !have_artifacts() {
        return;
    }
    let mut mk = |scheme: &str| -> f64 {
        let mut cfg = LmConfig::tiny();
        cfg.seed = 0x5ca1e;
        let mut t =
            LmTrainer::new(cfg, 8, scheme, LinkKind::Tcp25, &artifacts_dir()).unwrap();
        t.step().unwrap().emb_comm_time
    };
    let zen = mk("zen");
    let dense = mk("allreduce");
    assert!(
        zen < dense,
        "zen emb comm {zen} should be below allreduce {dense}"
    );
}
