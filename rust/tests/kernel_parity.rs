//! Scalar ↔ chunked kernel parity: every `zen::kernel::chunked` kernel
//! must be **bit-for-bit identical** to its `zen::kernel::scalar`
//! ground truth — not approximately equal. The chunked forms only
//! reassociate integer reductions (exact) and copy float runs verbatim,
//! so any divergence is a bug, and this suite compares the two
//! implementations directly (both are always compiled, regardless of
//! which one the `scalar_kernels` feature wires into the hot paths).
//!
//! Shapes exercised per kernel: empty, single element, block-aligned,
//! unaligned tails (every length around the 8-lane boundary), and
//! maximum density (all-ones bitmaps, fully-overlapping merges), at
//! worker counts n ∈ {2, 4, 8, 16} for the n-way merge.

use zen::hashing::HashFamily;
use zen::kernel::{chunked, scalar, LANES};
use zen::util::Pcg64;

/// Lengths that straddle the lane boundary: 0, 1, every count around
/// one block, around two blocks, and a large odd size.
fn lens() -> Vec<usize> {
    vec![0, 1, 3, 7, 8, 9, 15, 16, 17, 23, 24, 25, 64, 100, 1_000, 1_003]
}

fn words(rng: &mut Pcg64, n: usize) -> Vec<u64> {
    (0..n)
        .map(|_| (rng.next_u32() as u64) << 32 | rng.next_u32() as u64)
        .collect()
}

#[test]
fn or_words_matches_scalar() {
    let mut rng = Pcg64::seeded(seed_a());
    for n in lens() {
        let a = words(&mut rng, n);
        let b = words(&mut rng, n);
        let mut da = a.clone();
        let mut db = a.clone();
        scalar::or_words(&mut da, &b);
        chunked::or_words(&mut db, &b);
        assert_eq!(da, db, "n={n}");
    }
}

fn seed_a() -> u64 {
    0xa11ce
}

#[test]
fn and_count_and_popcount_match_scalar() {
    let mut rng = Pcg64::seeded(0xbeefcafe);
    for n in lens() {
        let a = words(&mut rng, n);
        let b = words(&mut rng, n);
        assert_eq!(
            scalar::and_count_words(&a, &b),
            chunked::and_count_words(&a, &b),
            "and n={n}"
        );
        assert_eq!(
            scalar::count_ones_words(&a),
            chunked::count_ones_words(&a),
            "popcount n={n}"
        );
        // max density: all-ones words
        let ones = vec![u64::MAX; n];
        assert_eq!(scalar::count_ones_words(&ones), n * 64);
        assert_eq!(chunked::count_ones_words(&ones), n * 64);
        assert_eq!(chunked::and_count_words(&ones, &ones), n * 64);
    }
}

/// Strictly ascending random index sequence of length `n` over
/// `0..range`, with values derived from the indices.
fn sorted_pairs(rng: &mut Pcg64, n: usize, range: u32) -> (Vec<u32>, Vec<f32>) {
    let mut idx: Vec<u32> = (0..n.min(range as usize))
        .map(|_| rng.next_u32() % range.max(1))
        .collect();
    idx.sort_unstable();
    idx.dedup();
    let val: Vec<f32> = idx
        .iter()
        .map(|&i| (i as f32) * 0.25 - (rng.next_u32() % 7) as f32)
        .collect();
    (idx, val)
}

#[test]
fn merge_sorted_matches_scalar_bitwise() {
    let mut rng = Pcg64::seeded(0x4e57);
    // (na, nb, range) grid: empty/single/unaligned/disjoint/dense
    let cases: Vec<(usize, usize, u32)> = vec![
        (0, 0, 10),
        (0, 5, 100),
        (1, 1, 2),
        (1, 1, 1_000),
        (7, 9, 64),
        (8, 8, 16),   // heavy overlap → Equal arm (float sums)
        (100, 3, 1_000_000), // long runs → bulk-copy fast path
        (3, 100, 1_000_000),
        (500, 500, 700), // max density: most indices shared
        (1_000, 1_000, 1_000_000),
    ];
    for (na, nb, range) in cases {
        let (ai, av) = sorted_pairs(&mut rng, na, range);
        let (bi, bv) = sorted_pairs(&mut rng, nb, range);
        let (mut si, mut sv) = (Vec::new(), Vec::new());
        let (mut ci, mut cv) = (Vec::new(), Vec::new());
        scalar::merge_sorted(&ai, &av, &bi, &bv, &mut si, &mut sv);
        chunked::merge_sorted(&ai, &av, &bi, &bv, &mut ci, &mut cv);
        assert_eq!(si, ci, "indices na={na} nb={nb} range={range}");
        // bit-for-bit float equality, not approximate
        let s_bits: Vec<u32> = sv.iter().map(|v| v.to_bits()).collect();
        let c_bits: Vec<u32> = cv.iter().map(|v| v.to_bits()).collect();
        assert_eq!(s_bits, c_bits, "values na={na} nb={nb} range={range}");
    }
}

#[test]
fn merge_sorted_nway_tree_matches_scalar() {
    // Tree-reduce n sequences with each kernel, the way
    // `CooTensor::merge_all` consumes merge_sorted, at n ∈ {2,4,8,16}.
    type Merge =
        fn(&[u32], &[f32], &[u32], &[f32], &mut Vec<u32>, &mut Vec<f32>);
    fn tree(parts: Vec<(Vec<u32>, Vec<f32>)>, merge: Merge) -> (Vec<u32>, Vec<f32>) {
        let mut layer = parts;
        while layer.len() > 1 {
            let mut next = Vec::with_capacity((layer.len() + 1) / 2);
            let mut it = layer.chunks(2);
            for pair in &mut it {
                if pair.len() == 2 {
                    let (mut oi, mut ov) = (Vec::new(), Vec::new());
                    merge(&pair[0].0, &pair[0].1, &pair[1].0, &pair[1].1, &mut oi, &mut ov);
                    next.push((oi, ov));
                } else {
                    next.push(pair[0].clone());
                }
            }
            layer = next;
        }
        layer.into_iter().next().unwrap_or_default()
    }
    for n in [2usize, 4, 8, 16] {
        let mut rng = Pcg64::seeded(0x7ee5 + n as u64);
        let parts: Vec<(Vec<u32>, Vec<f32>)> =
            (0..n).map(|_| sorted_pairs(&mut rng, 200, 2_000)).collect();
        let (si, sv) = tree(parts.clone(), scalar::merge_sorted);
        let (ci, cv) = tree(parts, chunked::merge_sorted);
        assert_eq!(si, ci, "n={n}");
        let s_bits: Vec<u32> = sv.iter().map(|v| v.to_bits()).collect();
        let c_bits: Vec<u32> = cv.iter().map(|v| v.to_bits()).collect();
        assert_eq!(s_bits, c_bits, "n={n}");
    }
}

#[test]
fn histogram_matches_scalar_on_every_byte() {
    let mut rng = Pcg64::seeded(0x415);
    for n in lens() {
        let keys: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        for shift in [0u32, 8, 16, 24] {
            let mut s = [1u32; 256]; // pre-dirtied: kernels must overwrite
            let mut c = [2u32; 256];
            scalar::histogram_u8(&keys, shift, &mut s);
            chunked::histogram_u8(&keys, shift, &mut c);
            assert_eq!(s, c, "n={n} shift={shift}");
            assert_eq!(s.iter().sum::<u32>() as usize, n, "total n={n}");
        }
    }
    // max density: every key in one bucket
    let same = vec![0xAB00u32; 1_001];
    let mut s = [0u32; 256];
    let mut c = [0u32; 256];
    scalar::histogram_u8(&same, 8, &mut s);
    chunked::histogram_u8(&same, 8, &mut c);
    assert_eq!(s, c);
    assert_eq!(s[0xAB], 1_001);
}

#[test]
fn domain_rank_matches_scalar() {
    let mut rng = Pcg64::seeded(0xd0_417);
    for n in lens() {
        let (domain, _) = sorted_pairs(&mut rng, n, (n as u32 * 3).max(8));
        // probe every member, every gap neighbor, and both extremes
        let mut probes: Vec<u32> = domain.clone();
        probes.extend(domain.iter().map(|&d| d.saturating_add(1)));
        probes.extend(domain.iter().map(|&d| d.saturating_sub(1)));
        probes.push(0);
        probes.push(u32::MAX);
        probes.sort_unstable();
        for start_frac in [0usize, 1, 2] {
            let start = domain.len() * start_frac / 3;
            for &p in &probes {
                assert_eq!(
                    scalar::domain_rank(&domain, start, p),
                    chunked::domain_rank(&domain, start, p),
                    "len={} start={start} probe={p}",
                    domain.len()
                );
            }
        }
    }
}

#[test]
fn partition_scatter_matches_scalar_visit_order() {
    let family = HashFamily::new(0x5eed, 4);
    let mut rng = Pcg64::seeded(0x5ca7);
    for n in lens() {
        for parts in [1usize, 2, 7, 16] {
            let h0 = family.partitioner(parts);
            let (indices, values) = sorted_pairs(&mut rng, n, 1 << 20);
            let mut s_visits: Vec<(usize, u32, u32)> = Vec::new();
            let mut c_visits: Vec<(usize, u32, u32)> = Vec::new();
            scalar::partition_scatter(
                |i| h0.partition(i),
                &indices,
                &values,
                |p, i, v| s_visits.push((p, i, v.to_bits())),
            );
            chunked::partition_scatter(
                |i| h0.partition(i),
                &indices,
                &values,
                |p, i, v| c_visits.push((p, i, v.to_bits())),
            );
            assert_eq!(s_visits, c_visits, "n={n} parts={parts}");
            assert_eq!(s_visits.len(), indices.len());
        }
    }
}

#[test]
fn select_topk_matches_scalar_and_a_sort_oracle() {
    // PR 9: the radix top-k selector behind the lossy compression tier.
    // Chunked must be bit-identical to scalar, and both must equal the
    // brute-force oracle: the k largest |v| keys, ties broken toward
    // the smallest index, output ascending. Duplicate magnitudes and
    // ±0.0 exercise the tie-rank path.
    let mut rng = Pcg64::seeded(0x70b5);
    for n in lens() {
        let mut values: Vec<f32> = (0..n)
            .map(|_| (rng.next_f32() * 2.0 - 1.0) * 8.0)
            .collect();
        // Inject duplicates and signed zeros at deterministic spots.
        for i in (0..n).step_by(5) {
            values[i] = if i % 2 == 0 { 0.5 } else { -0.5 };
        }
        if n > 2 {
            values[1] = 0.0;
            values[2] = -0.0;
        }
        for k in [0usize, 1, 2, n / 3, n.saturating_sub(1), n, n + 7] {
            let mut s = Vec::new();
            let mut c = Vec::new();
            scalar::select_topk(&values, k, &mut s);
            chunked::select_topk(&values, k, &mut c);
            assert_eq!(s, c, "n={n} k={k}: chunked diverges from scalar");

            // Oracle: sort by (|v| bits desc, index asc), take k, sort
            // the survivors ascending.
            let mut ranked: Vec<u32> = (0..n as u32).collect();
            ranked.sort_by_key(|&i| (std::cmp::Reverse(values[i as usize].abs().to_bits()), i));
            let mut expect: Vec<u32> = ranked.into_iter().take(k.min(n)).collect();
            expect.sort_unstable();
            assert_eq!(s, expect, "n={n} k={k}: selector diverges from oracle");
        }
    }
}

#[test]
fn lanes_is_the_documented_block_width() {
    // The suite's boundary lengths are built around this constant;
    // if LANES changes, lens() must be revisited.
    assert_eq!(LANES, 8);
}
