//! Topology-aware synchronization, end to end (the PR's acceptance
//! criteria):
//!
//! 1. On a 4×2 two-level topology with 10× slower inter-node links,
//!    `--scheme auto` (the cost planner) selects a *hierarchical*
//!    scheme for a bucket where the flat topology selects a
//!    non-hierarchical one.
//! 2. The executed plan reports predicted vs transport-measured time
//!    per link class, and the two agree on the dominant class.
//! 3. Every scheme completes without panic for machine counts
//!    {3, 5, 6, 12} (the non-power-of-two fold paths).

use zen::cluster::{LinkClass, LinkKind, Network, Topology};
use zen::planner::{CostPlanner, PlanConfig, Planner};
use zen::schemes::{self, CommPattern, SyncScratch};
use zen::workload::{group_clustered_inputs, random_uniform_inputs};

/// 10×-heterogeneous links, zero latency so the crossover is a pure
/// bandwidth statement (stage counts don't tip near-ties).
fn inter_link() -> LinkKind {
    LinkKind::Custom(25_000_000_000, 0)
}

fn intra_link() -> LinkKind {
    LinkKind::Custom(250_000_000_000, 0)
}

fn comm_pattern(name: &str, n: usize) -> CommPattern {
    schemes::by_name(name, n, 1, 64)
        .unwrap_or_else(|| panic!("chosen scheme '{name}' must construct"))
        .dims()
        .communication
}

/// The workload where placement matters: co-located ranks (and the
/// node pairs of one "rack") share their gradient support, so the
/// union density stays flat across the first half of the workers.
fn clustered(n: usize) -> Vec<zen::tensor::CooTensor> {
    group_clustered_inputs(0x70b0, 2, n / 2, 1 << 18, 0.01)
}

#[test]
fn auto_flips_to_hierarchical_scheme_on_two_level_topology() {
    let n = 8;
    let inputs = clustered(n);
    let flat = Topology::flat(n, inter_link());
    let two_level = Topology::two_level(4, 2, intra_link(), inter_link());

    let flat_planner = CostPlanner::new(n, 0x5eed, 4096, PlanConfig::default());
    let flat_choice = flat_planner.plan("bucket", &inputs, &flat);
    let topo_planner = CostPlanner::new(n, 0x5eed, 4096, PlanConfig::default());
    let topo_choice = topo_planner.plan("bucket", &inputs, &two_level);

    let flat_chosen = flat_choice.plan.as_ref().unwrap().chosen;
    let topo_chosen = topo_choice.plan.as_ref().unwrap().chosen;
    assert_ne!(
        comm_pattern(flat_chosen, n),
        CommPattern::Hierarchy,
        "flat mesh must not pick a hierarchical scheme here (picked {flat_chosen})"
    );
    assert_eq!(
        comm_pattern(topo_chosen, n),
        CommPattern::Hierarchy,
        "4x2 with 10x slower inter links must pick a hierarchical scheme \
         (picked {topo_chosen}; flat picked {flat_chosen})"
    );

    // The flip is the planner's honest prediction of execution: run
    // both choices on the two-level transport and confirm the
    // hierarchical pick really is faster there.
    let net = Network::with_topology(two_level);
    let t_topo = topo_choice
        .scheme
        .run_sim(&inputs, &net, &mut SyncScratch::new())
        .report
        .comm_time();
    let t_flat_pick = flat_choice
        .scheme
        .run_sim(&inputs, &net, &mut SyncScratch::new())
        .report
        .comm_time();
    assert!(
        t_topo < t_flat_pick,
        "hierarchical pick must beat the flat pick on the two-level fabric: \
         {topo_chosen} {t_topo:.3e}s vs {flat_chosen} {t_flat_pick:.3e}s"
    );
}

#[test]
fn plan_reports_predicted_vs_measured_per_link_class() {
    let n = 8;
    let inputs = clustered(n);
    let two_level = Topology::two_level(4, 2, intra_link(), inter_link());
    let planner = CostPlanner::new(n, 0x5eed, 4096, PlanConfig::default());
    let planned = planner.plan("bucket", &inputs, &two_level);
    let plan = planned.plan.as_ref().unwrap();

    let predicted = plan.predicted_class_at_scale(1.0);
    assert!(predicted[LinkClass::Inter.idx()] > 0.0, "inter predicted");
    assert!(predicted[LinkClass::Intra.idx()] > 0.0, "intra predicted");

    let net = Network::with_topology(two_level);
    let report = planned
        .scheme
        .run_sim(&inputs, &net, &mut SyncScratch::new())
        .report;
    let measured = report.time_by_class();
    assert!(measured[LinkClass::Inter.idx()] > 0.0, "inter measured");
    assert!(measured[LinkClass::Intra.idx()] > 0.0, "intra measured");
    // The dominant (inter) class prediction must land in the measured
    // ballpark — frame headers and discreteness allow slack, an
    // order-of-magnitude gap would mean model and transport diverged.
    let inter = LinkClass::Inter.idx();
    let ratio = measured[inter] / predicted[inter].max(1e-18);
    assert!(
        (0.5..=2.0).contains(&ratio),
        "inter measured/predicted = {ratio} (measured {measured:?}, predicted {predicted:?})"
    );
    // The inter-class charge dominates total stage time under 10×
    // slower fabric links.
    assert!(
        report.comm_time() >= measured[inter],
        "stage max cannot be below the inter sum"
    );
}

#[test]
fn all_schemes_complete_on_non_pow2_machine_counts() {
    for &n in &[3usize, 5, 6, 12] {
        let inputs = random_uniform_inputs(0xacc ^ n as u64, n, 3_000, 0.02);
        let nnz = inputs[0].nnz().max(8);
        let net = Network::new(n, LinkKind::Tcp25);
        for name in [
            "dense",
            "agsparse",
            "agsparse-ring",
            "agsparse-hier",
            "sparcml",
            "sparseps",
            "omnireduce",
            "zen",
            "zen-coo",
        ] {
            let scheme = schemes::by_name(name, n, 0xacc, nnz).unwrap();
            let r = scheme.run_sim(&inputs, &net, &mut SyncScratch::new());
            schemes::verify_outputs(&r, &inputs);
        }
    }
}

#[test]
fn uniform_workload_keeps_flat_choice_on_two_level() {
    // Without placement-correlated sparsity the hierarchy has no edge:
    // the planner's two-level choice stays non-hierarchical, proving
    // the flip above is driven by the measured d(j) structure, not a
    // bias in the topology pricing.
    let n = 8;
    let inputs = random_uniform_inputs(0x1111, n, 1 << 18, 0.01);
    let two_level = Topology::two_level(4, 2, intra_link(), inter_link());
    let planner = CostPlanner::new(n, 0x5eed, 4096, PlanConfig::default());
    let chosen = planner
        .plan("bucket", &inputs, &two_level)
        .plan
        .unwrap()
        .chosen;
    assert_ne!(comm_pattern(chosen, n), CommPattern::Hierarchy, "{chosen}");
}
