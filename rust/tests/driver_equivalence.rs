//! Driver-equivalence suite (PR 6 acceptance): the same sans-IO
//! protocol machines must behave identically under every IO shell.
//!
//! 1. Every scheme × n ∈ {2, 3, 4, 5, 8} × {sim, channel, event,
//!    socket}: per-stage sent/recv byte vectors and α–β stage times
//!    equal across drivers, outputs bit-identical, lossless schemes
//!    reference-exact. The discrete-event driver additionally proves
//!    its virtual clock equals the report's comm time in exact f64
//!    arithmetic (PR 7 acceptance).
//! 2. Two-process smoke: `zen worker --listen` / `--connect` in two OS
//!    processes complete the sync, print equal output digests, and
//!    report the same total bytes as the in-process run.
//! 3. Peer kill: a worker whose peer connects and immediately dies
//!    exits with an error (`WireError::Disconnected` path), not a hang.

use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use zen::cluster::{LinkKind, Network};
use zen::schemes::{self, SyncScheme, SyncScratch};
use zen::tensor::CooTensor;
use zen::util::Pcg64;
use zen::wire::{make_driver, EventDriver, TransportKind};
use zen::workload::random_uniform_inputs as random_inputs;

const ALL_SCHEMES: &[&str] = &[
    "dense",
    "agsparse",
    "agsparse-ring",
    "agsparse-hier",
    "sparcml",
    "sparseps",
    "omnireduce",
    "zen",
    "zen-coo",
    "oktopk",
    "strawman:8",
];

/// Whether loopback sockets work in this environment (sandboxes may
/// forbid them); checked once per process.
fn sockets_available() -> bool {
    TcpListener::bind("127.0.0.1:0").is_ok()
}

fn equivalence_cell(name: &str, machines: usize, with_socket: bool) {
    let dense_len = 4_000;
    let inputs = random_inputs(0xd21 ^ machines as u64, machines, dense_len, 0.03);
    let nnz = inputs[0].nnz().max(8);
    let scheme = schemes::by_name(name, machines, 0x7ace, nnz).unwrap();
    let net = Network::new(machines, LinkKind::Tcp25);
    let ctx = format!("{name} m={machines}");

    let mut kinds = vec![
        TransportKind::Sim,
        TransportKind::Channel,
        TransportKind::Event,
    ];
    if with_socket {
        kinds.push(TransportKind::Socket);
    }
    let mut baseline: Option<(TransportKind, zen::schemes::SyncOutput)> = None;
    for kind in kinds {
        let mut drv = make_driver(kind, &net)
            .unwrap_or_else(|e| panic!("{ctx}: {} driver setup: {e}", kind.name()));
        let got = scheme
            .run(&inputs, drv.as_mut(), &mut SyncScratch::new())
            .unwrap_or_else(|e| panic!("{ctx}: {} sync failed: {e}", kind.name()));
        match &baseline {
            None => {
                if !name.starts_with("strawman") {
                    schemes::verify_outputs(&got, &inputs);
                }
                baseline = Some((kind, got));
            }
            Some((base_kind, base)) => {
                let pair = format!("{ctx}: {} vs {}", base_kind.name(), kind.name());
                assert_eq!(
                    base.report.stages.len(),
                    got.report.stages.len(),
                    "{pair}: stage count"
                );
                for (s, c) in base.report.stages.iter().zip(got.report.stages.iter()) {
                    assert_eq!(s.name, c.name, "{pair}: stage name");
                    assert_eq!(s.sent, c.sent, "{pair}: stage '{}' sent", s.name);
                    assert_eq!(s.recv, c.recv, "{pair}: stage '{}' recv", s.name);
                    assert_eq!(s.time, c.time, "{pair}: stage '{}' time", s.name);
                    assert_eq!(
                        s.classes, c.classes,
                        "{pair}: stage '{}' class split",
                        s.name
                    );
                }
                assert_eq!(base.outputs, got.outputs, "{pair}: outputs diverge");
            }
        }
    }

    // The event driver's virtual clock is the sum of its stage charges —
    // exactly the report's comm time, in the same f64 additions.
    let mut ev = EventDriver::new(net.clone());
    let got = scheme
        .run(&inputs, &mut ev, &mut SyncScratch::new())
        .unwrap_or_else(|e| panic!("{ctx}: event sync failed: {e}"));
    assert_eq!(
        ev.virtual_time(),
        got.report.comm_time(),
        "{ctx}: event virtual clock != report comm time"
    );
    assert_eq!(
        baseline.as_ref().unwrap().1.outputs,
        got.outputs,
        "{ctx}: event outputs diverge from baseline"
    );
}

#[test]
fn every_scheme_equivalent_across_drivers() {
    let with_socket = sockets_available();
    if !with_socket {
        eprintln!("loopback sockets unavailable; covering sim vs channel only");
    }
    for &machines in &[2usize, 3, 4, 5, 8] {
        for name in ALL_SCHEMES {
            equivalence_cell(name, machines, with_socket);
        }
    }
}

/// PR 9 acceptance: compressed synchronization is driver-invariant.
/// The compressor emits ordinary `CooTensor`s, so every scheme must
/// ship identical per-stage bytes and bit-identical outputs across
/// sim/channel/event (and socket where available) when the inputs went
/// through error-feedback Top-k first — same invariant the raw inputs
/// satisfy, at post-compression density.
fn compressed_equivalence_cell(name: &str, machines: usize, with_socket: bool) {
    use zen::compress::{compress_all, CompressSpec};
    let dense_len = 4_000;
    let raw = random_inputs(0xc0de ^ machines as u64, machines, dense_len, 0.03);
    let mut compressor = CompressSpec::TopK(0.01).build().unwrap();
    let inputs = compress_all(compressor.as_mut(), "eq", &raw);
    for (t, r) in inputs.iter().zip(raw.iter()) {
        assert!(t.nnz() < r.nnz(), "top-k must reduce nnz in this cell");
    }
    let nnz = inputs[0].nnz().max(8);
    let scheme = schemes::by_name(name, machines, 0x7ace, nnz).unwrap();
    let net = Network::new(machines, LinkKind::Tcp25);
    let ctx = format!("compressed {name} m={machines}");

    let mut kinds = vec![
        TransportKind::Sim,
        TransportKind::Channel,
        TransportKind::Event,
    ];
    if with_socket {
        kinds.push(TransportKind::Socket);
    }
    let mut baseline: Option<(TransportKind, zen::schemes::SyncOutput)> = None;
    for kind in kinds {
        let mut drv = make_driver(kind, &net)
            .unwrap_or_else(|e| panic!("{ctx}: {} driver setup: {e}", kind.name()));
        let got = scheme
            .run(&inputs, drv.as_mut(), &mut SyncScratch::new())
            .unwrap_or_else(|e| panic!("{ctx}: {} sync failed: {e}", kind.name()));
        match &baseline {
            None => {
                // The sync itself stays lossless: outputs must equal
                // the sum of the *compressed* inputs exactly.
                if !name.starts_with("strawman") {
                    schemes::verify_outputs(&got, &inputs);
                }
                baseline = Some((kind, got));
            }
            Some((base_kind, base)) => {
                let pair = format!("{ctx}: {} vs {}", base_kind.name(), kind.name());
                for (s, c) in base.report.stages.iter().zip(got.report.stages.iter()) {
                    assert_eq!(s.sent, c.sent, "{pair}: stage '{}' sent", s.name);
                    assert_eq!(s.recv, c.recv, "{pair}: stage '{}' recv", s.name);
                    assert_eq!(s.time, c.time, "{pair}: stage '{}' time", s.name);
                }
                assert_eq!(
                    base.report.stages.len(),
                    got.report.stages.len(),
                    "{pair}: stage count"
                );
                assert_eq!(base.outputs, got.outputs, "{pair}: outputs diverge");
            }
        }
    }
}

#[test]
fn every_scheme_equivalent_across_drivers_compressed() {
    let with_socket = sockets_available();
    for &machines in &[2usize, 4, 8] {
        for name in ALL_SCHEMES {
            compressed_equivalence_cell(name, machines, with_socket);
        }
    }
}

// ---- two-process worker smoke --------------------------------------

/// Same derivation as `zen worker` (main.rs `worker_inputs`): both test
/// and processes must agree on the gradients byte-for-byte.
fn worker_inputs(seed: u64, n: usize, dense_len: usize, shared: usize, private: usize) -> Vec<CooTensor> {
    let mut rng = Pcg64::seeded(seed);
    let hot: Vec<usize> = rng.sample_distinct(dense_len, shared);
    (0..n)
        .map(|w| {
            let mut idx: Vec<u32> = hot.iter().map(|&i| i as u32).collect();
            let mut priv_rng = Pcg64::new(seed ^ w as u64, 55);
            for _ in 0..private {
                idx.push(priv_rng.below(dense_len as u64) as u32);
            }
            idx.sort_unstable();
            idx.dedup();
            let vals: Vec<f32> = idx
                .iter()
                .map(|_| priv_rng.next_f32() * 2.0 - 1.0)
                .map(|v| if v == 0.0 { 0.5 } else { v })
                .collect();
            CooTensor::from_sorted(dense_len, idx, vals)
        })
        .collect()
}

/// FNV-1a mirror of the binary's output fingerprint.
fn fnv_digest(t: &CooTensor) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |h: &mut u64, bytes: &[u8]| {
        for &b in bytes {
            *h ^= b as u64;
            *h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    eat(&mut h, &(t.dense_len as u64).to_le_bytes());
    for &i in &t.indices {
        eat(&mut h, &i.to_le_bytes());
    }
    for &v in &t.values {
        eat(&mut h, &v.to_bits().to_le_bytes());
    }
    h
}

/// Reserve a loopback port: bind to 0, read the assignment, release.
fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0")
        .expect("bind ephemeral")
        .local_addr()
        .expect("local addr")
        .port()
}

fn spawn_worker(role: &str, addr: &str, scheme: &str, seed: u64) -> Child {
    Command::new(env!("CARGO_BIN_EXE_zen"))
        .args([
            "worker",
            role,
            addr,
            "--scheme",
            scheme,
            "--dense-len",
            "8000",
            "--shared",
            "400",
            "--private",
            "150",
            "--seed",
            &seed.to_string(),
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn zen worker")
}

fn wait_with_deadline(mut child: Child, what: &str) -> (String, String, bool) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match child.try_wait().expect("try_wait") {
            Some(status) => {
                let mut out = String::new();
                let mut err = String::new();
                child.stdout.take().unwrap().read_to_string(&mut out).ok();
                child.stderr.take().unwrap().read_to_string(&mut err).ok();
                return (out, err, status.success());
            }
            None if Instant::now() > deadline => {
                child.kill().ok();
                panic!("{what}: worker did not exit within 30s");
            }
            None => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

/// Parse `bytes=N digest=H` off the worker's report line.
fn parse_report(stdout: &str, what: &str) -> (u64, u64) {
    let line = stdout
        .lines()
        .find(|l| l.contains("digest="))
        .unwrap_or_else(|| panic!("{what}: no report line in {stdout:?}"));
    let field = |key: &str| {
        line.split_whitespace()
            .find_map(|tok| tok.strip_prefix(key))
            .unwrap_or_else(|| panic!("{what}: missing {key} in {line:?}"))
            .to_string()
    };
    let bytes: u64 = field("bytes=").parse().expect("bytes field");
    let digest = u64::from_str_radix(&field("digest="), 16).expect("digest field");
    (bytes, digest)
}

#[test]
fn two_process_worker_sync_matches_in_process() {
    if !sockets_available() {
        eprintln!("loopback sockets unavailable; skipping worker smoke");
        return;
    }
    let seed = 0x2e2u64;
    for scheme_name in ["zen", "dense"] {
        let addr = format!("127.0.0.1:{}", free_port());
        let listener = spawn_worker("--listen", &addr, scheme_name, seed);
        let connector = spawn_worker("--connect", &addr, scheme_name, seed);
        let (out0, err0, ok0) = wait_with_deadline(listener, "listener");
        let (out1, err1, ok1) = wait_with_deadline(connector, "connector");
        assert!(ok0, "{scheme_name}: listener failed: {err0}\n{out0}");
        assert!(ok1, "{scheme_name}: connector failed: {err1}\n{out1}");
        let (bytes0, digest0) = parse_report(&out0, "listener");
        let (bytes1, digest1) = parse_report(&out1, "connector");
        assert_eq!(digest0, digest1, "{scheme_name}: aggregates diverge across processes");

        // In-process ground truth: same inputs, same scheme, virtual
        // time. Both workers observe the full 2-rank byte matrix, so
        // all three totals must agree.
        let inputs = worker_inputs(seed, 2, 8_000, 400, 150);
        let nnz = 400 + 150;
        let scheme = schemes::by_name(scheme_name, 2, seed ^ 0x5eed, nnz).unwrap();
        let net = Network::new(2, LinkKind::Tcp25);
        let reference = scheme.run_sim(&inputs, &net, &mut SyncScratch::new());
        assert_eq!(bytes0, reference.report.total_bytes(), "{scheme_name}: listener bytes");
        assert_eq!(bytes1, reference.report.total_bytes(), "{scheme_name}: connector bytes");
        assert_eq!(
            digest0,
            fnv_digest(&reference.outputs[0]),
            "{scheme_name}: worker aggregate differs from in-process"
        );
    }
}

#[test]
fn worker_surfaces_peer_death_as_error_not_hang() {
    if !sockets_available() {
        eprintln!("loopback sockets unavailable; skipping peer-kill test");
        return;
    }
    let addr = format!("127.0.0.1:{}", free_port());
    let listener = spawn_worker("--listen", &addr, "zen", 7);
    // A "peer" that connects and immediately dies mid-handshake.
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        match TcpStream::connect(&addr) {
            Ok(s) => {
                drop(s);
                break;
            }
            Err(_) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(20))
            }
            Err(e) => panic!("could not reach listening worker: {e}"),
        }
    }
    let (out, err, ok) = wait_with_deadline(listener, "peer-kill");
    assert!(
        !ok,
        "worker must exit with an error after its peer dies, got: {out}"
    );
    assert!(
        err.to_lowercase().contains("disconnect"),
        "stderr should surface the disconnect: {err:?}"
    );
}
