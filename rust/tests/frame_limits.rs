//! Wire-size limits: every count the codec encodes as a `u32` must be
//! rejected with a typed error when it would not fit one, instead of
//! being silently truncated by `as u32` (the old behavior corrupted the
//! frame's length fields for nnz ≥ 2^32). The boundary is probed with
//! length-only synthetic counts — no 4-billion-element allocations —
//! through the same helpers [`FrameRef::validate`] dispatches to, plus
//! an end-to-end check that transports reject invalid frames before
//! charging any bytes.

use zen::cluster::{LinkKind, Network};
use zen::tensor::CooTensor;
use zen::wire::codec::{
    blocks_frame_counts, coo_frame_counts, dense_chunk_frame_counts, hash_bitmap_frame_counts,
    validate_frame_counts, Decode, Encode,
};
use zen::wire::{ChannelTransport, FrameRef, Message, SimTransport, Transport, WireError};

const U32_MAX: u64 = u32::MAX as u64;

fn ok(counts: &[(&'static str, u64)]) -> bool {
    validate_frame_counts(counts).is_ok()
}

fn rejected_field(counts: &[(&'static str, u64)]) -> &'static str {
    match validate_frame_counts(counts) {
        Err(WireError::FrameTooLarge { what, .. }) => what,
        other => panic!("expected FrameTooLarge, got {other:?}"),
    }
}

#[test]
fn coo_nnz_boundary() {
    // The body length (16 + 8·nnz) overflows u32 long before the nnz
    // field itself: the largest encodable COO frame holds
    // (u32::MAX − 16) / 8 entries.
    let max_nnz = (U32_MAX - 16) / 8;
    assert!(ok(&coo_frame_counts(max_nnz)), "just below the limit");
    assert_eq!(rejected_field(&coo_frame_counts(max_nnz + 1)), "body length");
    // nnz beyond u32 is also caught in its own right
    assert_eq!(rejected_field(&coo_frame_counts(U32_MAX + 1)), "coo nnz");
}

#[test]
fn dense_chunk_boundary() {
    let max_count = (U32_MAX - 16) / 4;
    assert!(ok(&dense_chunk_frame_counts(max_count)));
    assert_eq!(
        rejected_field(&dense_chunk_frame_counts(max_count + 1)),
        "body length"
    );
    assert_eq!(
        rejected_field(&dense_chunk_frame_counts(U32_MAX + 1)),
        "dense chunk count"
    );
}

#[test]
fn blocks_boundary() {
    // nblocks · block_len (the value count) carries its own u32 field.
    assert!(ok(&blocks_frame_counts(1_000, 4)));
    assert_eq!(
        rejected_field(&blocks_frame_counts(U32_MAX + 1, 1)),
        "block count"
    );
    // counts fit individually but the product overflows
    assert_eq!(
        rejected_field(&blocks_frame_counts(1 << 20, 1 << 13)),
        "block value count"
    );
    // product fits u32 but the 4-byte-per-value body does not
    let nblocks = (U32_MAX / 4 / 64) + 1;
    assert_eq!(rejected_field(&blocks_frame_counts(nblocks, 64)), "body length");
}

#[test]
fn hash_bitmap_boundary() {
    // Bitmap bits travel as u64 (no truncation risk); the value count
    // and the word-padded body are the u32-bound fields.
    assert!(ok(&hash_bitmap_frame_counts(1 << 20, 1 << 15)));
    assert_eq!(
        rejected_field(&hash_bitmap_frame_counts(64, U32_MAX + 1)),
        "bitmap value count"
    );
    // a bitmap alone can outgrow the body length field: > 2^32 bytes of
    // words means > 2^35 bits
    assert_eq!(
        rejected_field(&hash_bitmap_frame_counts(1u64 << 36, 0)),
        "body length"
    );
}

#[test]
fn saturating_arithmetic_never_wraps() {
    // Absurd synthetic counts must still land in FrameTooLarge, not
    // wrap around u64 into a "valid" small body.
    assert!(validate_frame_counts(&coo_frame_counts(u64::MAX)).is_err());
    assert!(validate_frame_counts(&blocks_frame_counts(u64::MAX, u64::MAX)).is_err());
    assert!(validate_frame_counts(&hash_bitmap_frame_counts(u64::MAX, u64::MAX)).is_err());
    assert!(validate_frame_counts(&dense_chunk_frame_counts(u64::MAX)).is_err());
}

#[test]
fn transports_validate_before_charging() {
    // End-to-end: a frame with an in-range slice but an invalid
    // declared block geometry is refused by `send` on both in-process
    // backends, and nothing is charged to the stage.
    let net = Network::new(2, LinkKind::Tcp25);
    let ids = [0u32];
    let values = [0.0f32; 8];
    // block_len u32::MAX with 1 block: value count fits, body length
    // computation must reject without any allocation.
    let bad = FrameRef::Blocks {
        from: 0,
        dense_len: u64::MAX,
        block_len: u32::MAX,
        block_ids: &ids,
        values: &values,
    };
    let mut sim = SimTransport::new(net.clone());
    assert!(matches!(
        sim.send(0, 1, bad),
        Err(WireError::FrameTooLarge { .. })
    ));
    sim.end_stage("clean").expect("nothing in flight");
    assert_eq!(sim.take_report().stages[0].total_bytes(), 0);

    let mut ch = ChannelTransport::new(net);
    assert!(matches!(
        ch.send(0, 1, bad),
        Err(WireError::FrameTooLarge { .. })
    ));
    ch.end_stage("clean").expect("nothing in flight");
    assert_eq!(ch.take_report().stages[0].total_bytes(), 0);
}

// --- Decode-side boundaries: the `try_from` paths that replaced the
// old `as usize` casts must reject forged length fields with a typed
// error, never size a buffer from them. Frames are forged by encoding a
// valid message and overwriting one header field in place (the frame
// layout is header(8) = magic(2) version(1) kind(1) body_len(4), then
// the per-kind metadata documented on each variant).

fn encode_msg(m: &Message) -> Vec<u8> {
    let mut buf = Vec::new();
    m.encode(&mut buf);
    buf
}

#[test]
fn decode_rejects_coo_index_beyond_forged_dense_len() {
    // Shrink the declared dense range under the encoded indices: the
    // range check must fire instead of trusting the forged length.
    let t = CooTensor::from_sorted(100, vec![5, 50], vec![1.0, 2.0]);
    let mut buf = encode_msg(&Message::PushCoo { from: 0, tensor: t });
    buf[12..20].copy_from_slice(&6u64.to_le_bytes()); // dense_len after header + from
    assert!(matches!(
        Message::decode(&buf),
        Err(WireError::Malformed("index out of range"))
    ));
}

#[test]
fn decode_rejects_unsorted_coo_indices() {
    let t = CooTensor::from_sorted(100, vec![5, 50], vec![1.0, 2.0]);
    let mut buf = encode_msg(&Message::PushCoo { from: 0, tensor: t });
    // indices start after header + from(4) + dense_len(8) + nnz(4)
    buf[24..28].copy_from_slice(&50u32.to_le_bytes());
    buf[28..32].copy_from_slice(&5u32.to_le_bytes());
    assert!(matches!(
        Message::decode(&buf),
        Err(WireError::Malformed("indices not strictly ascending"))
    ));
}

#[test]
fn decode_rejects_implausible_bitmap_bits() {
    let mut payload = zen::hashing::HashBitmapPayload::default();
    payload.bitmap.reset(64);
    payload.bitmap.set(3);
    let msg = Message::PullHashBitmap {
        server: 0,
        bitmap: payload.bitmap.clone(),
        values: vec![1.0],
    };
    let mut buf = encode_msg(&msg);
    // bits u64 after header + server(4): claim > 2^40 bits
    buf[12..20].copy_from_slice(&((1u64 << 40) + 1).to_le_bytes());
    assert!(matches!(
        Message::decode(&buf),
        Err(WireError::Malformed("bitmap length implausible"))
    ));
}

#[test]
fn decode_rejects_forged_block_geometry() {
    let msg = Message::Blocks {
        from: 0,
        dense_len: 256,
        block_len: 4,
        block_ids: vec![0, 1],
        values: vec![0.0; 8],
    };
    // block_len u32 sits after header + from(4) + dense_len(8).
    let mut zero_len = encode_msg(&msg);
    zero_len[20..24].copy_from_slice(&0u32.to_le_bytes());
    assert!(matches!(
        Message::decode(&zero_len),
        Err(WireError::Malformed("zero block length"))
    ));
    // Both u32 size fields in range, but their product overflows the
    // value-count bound: must be rejected before any allocation.
    let mut huge = encode_msg(&msg);
    huge[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
    huge[24..28].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(
        Message::decode(&huge),
        Err(WireError::Malformed("implausible block payload"))
    ));
}

#[test]
fn decode_rejects_truncated_counts() {
    // nnz forged above the actual payload: the reader must report the
    // shortfall, not read past the buffer.
    let t = CooTensor::from_sorted(100, vec![5, 50], vec![1.0, 2.0]);
    let mut buf = encode_msg(&Message::PushCoo { from: 0, tensor: t });
    buf[20..24].copy_from_slice(&1_000u32.to_le_bytes()); // nnz field
    assert!(matches!(
        Message::decode(&buf),
        Err(WireError::Truncated { .. })
    ));
}
