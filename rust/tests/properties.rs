//! Property-based integration tests over the coordinator-level
//! invariants: any scheme × any workload shape must aggregate exactly,
//! the hierarchical hasher must stay lossless and consistent, and the
//! hash-bitmap codec must round-trip — all under randomized shapes.

use zen::cluster::{LinkKind, Network};
use zen::hashing::{HashBitmapCodec, HierarchicalHasher};
use zen::schemes::{self, SyncScheme, SyncScratch};
use zen::tensor::CooTensor;
use zen::util::propcheck::{check_seeded, prop_assert};

fn random_inputs(g: &mut zen::util::propcheck::Gen, n: usize, dense_len: usize) -> Vec<CooTensor> {
    (0..n)
        .map(|_| {
            let nnz = g.usize_in(0, (dense_len / 2).min(300));
            let idx = g.distinct_sorted_u32(nnz, dense_len as u32);
            let vals: Vec<f32> = (0..nnz)
                .map(|_| (g.f64_unit() as f32) * 2.0 - 1.0)
                .map(|v| if v == 0.0 { 0.25 } else { v })
                .collect();
            CooTensor::from_sorted(dense_len, idx, vals)
        })
        .collect()
}

#[test]
fn prop_any_scheme_any_workload_aggregates_exactly() {
    check_seeded(0xa11, 60, |g| {
        let n = g.usize_in(1, 9);
        let dense_len = g.usize_in(n.max(4), 3_000);
        let inputs = random_inputs(g, n, dense_len);
        let net = Network::new(n, LinkKind::Tcp25);
        let nnz = inputs[0].nnz().max(8);
        let which = g.usize_in(0, 5);
        let name = ["dense", "agsparse", "sparcml", "sparseps", "omnireduce", "zen"][which];
        let scheme = schemes::by_name(name, n, g.u64(), nnz).unwrap();
        let r = scheme.run_sim(&inputs, &net, &mut SyncScratch::new());
        // exact dense-sum equivalence within float tolerance
        let reference = schemes::reference_sum(&inputs);
        for out in &r.outputs {
            let d = out.to_dense();
            for i in 0..dense_len {
                let (a, b) = (d.values[i], reference.values[i]);
                if (a - b).abs() > 1e-4_f32.max(b.abs() * 1e-4) {
                    return Err(format!("{name}: idx {i} {a} != {b}"));
                }
            }
        }
        // traffic accounting sanity: payload bound plus per-frame framing
        // slack (≤ ~2n² frames of ≤ 32 B fixed overhead per sync)
        let payload_bound = (dense_len as u64 + 1) * 16 * n as u64 * n as u64;
        let framing_slack = 64 * (n as u64 + 1) * (n as u64 + 1);
        prop_assert(
            r.report.total_bytes() < payload_bound + framing_slack,
            "traffic bounded",
        )
    });
}

#[test]
fn prop_hasher_lossless_and_worker_consistent() {
    check_seeded(0xb22, 80, |g| {
        let dense_len = g.usize_in(16, 5_000);
        let n = g.usize_in(1, 10);
        let seed = g.u64();
        let h = HierarchicalHasher::new(
            seed,
            n,
            g.usize_in(1, 4),
            g.usize_in(4, 128),
            g.usize_in(1, 16),
        );
        // two "workers" with overlapping index sets
        let a_nnz = g.usize_in(0, 200.min(dense_len));
        let b_nnz = g.usize_in(0, 200.min(dense_len));
        let a_idx = g.distinct_sorted_u32(a_nnz, dense_len as u32);
        let b_idx = g.distinct_sorted_u32(b_nnz, dense_len as u32);
        let a = CooTensor::from_sorted(dense_len, a_idx, vec![1.0; a_nnz]);
        let b = CooTensor::from_sorted(dense_len, b_idx, vec![2.0; b_nnz]);
        let oa = h.partition(&a);
        let ob = h.partition(&b);
        // lossless
        if CooTensor::merge_all(&oa.parts) != a {
            return Err("worker A lost data".into());
        }
        if CooTensor::merge_all(&ob.parts) != b {
            return Err("worker B lost data".into());
        }
        // consistency: shared indices land in the same partition
        for p in 0..n {
            for &idx in &oa.parts[p].indices {
                if b.indices.binary_search(&idx).is_ok() {
                    let in_b = ob.parts[p].indices.binary_search(&idx).is_ok();
                    if !in_b {
                        return Err(format!("index {idx} split across partitions"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hash_bitmap_roundtrip_through_hasher() {
    check_seeded(0xc33, 60, |g| {
        let dense_len = g.usize_in(16, 4_000);
        let n = g.usize_in(1, 8);
        let h = HierarchicalHasher::with_defaults(g.u64(), n, 64);
        let nnz = g.usize_in(0, 200.min(dense_len));
        let idx = g.distinct_sorted_u32(nnz, dense_len as u32);
        let t = CooTensor::from_sorted(dense_len, idx, vec![1.5; nnz]);
        let parts = h.partition(&t).parts;
        let domains = h.partition_domains(dense_len);
        for p in 0..n {
            let codec = HashBitmapCodec::new(&domains[p]);
            let payload = codec.encode(&parts[p]);
            if codec.decode(&payload, dense_len) != parts[p] {
                return Err(format!("partition {p} roundtrip failed"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hasher_lossless_and_theorem2_bound_across_n() {
    // ISSUE 2: losslessness (union of partitions == input, no
    // duplicates) and the Theorem-2 balance bound
    // `1 + O(√(n log n / nnz))` must hold for every server count the
    // paper evaluates, across uniform, clustered, and strided non-zero
    // patterns — exercised through the scratch path so the reused
    // buffers are covered at integration level too.
    use zen::hashing::PartitionScratch;
    // RefCell: check_seeded takes Fn, and the scratch must persist
    // across cases to prove reuse never leaks state between runs.
    let scratch = std::cell::RefCell::new(PartitionScratch::new());
    for n in [2usize, 4, 8, 16] {
        check_seeded(0xe55 + n as u64, 12, |g| {
            let dense_len = g.usize_in(60_000, 250_000);
            let nnz = g.usize_in(3_000, 10_000);
            let idx: Vec<u32> = match g.usize_in(0, 2) {
                // uniform over the full range
                0 => g.distinct_sorted_u32(nnz, dense_len as u32),
                // clustered into the hot 4% prefix (skewness, Fig 2)
                1 => g.distinct_sorted_u32(nnz, (dense_len / 25).max(nnz) as u32),
                // strided (embedding-row structure): every 16th index
                _ => {
                    let set: std::collections::BTreeSet<u32> =
                        (0..nnz as u32).map(|i| i * 16 % dense_len as u32).collect();
                    set.into_iter().collect()
                }
            };
            let nnz = idx.len();
            let vals: Vec<f32> = (0..nnz).map(|i| i as f32 * 0.5 + 1.0).collect();
            let t = CooTensor::from_sorted(dense_len, idx, vals);
            let h = HierarchicalHasher::with_defaults(g.u64(), n, nnz);
            let mut scratch = scratch.borrow_mut();
            h.partition_into(&t, &mut scratch);
            // losslessness: union of partitions == input, no dup, no loss
            let parts: Vec<CooTensor> = (0..n).map(|p| scratch.part(p).to_tensor()).collect();
            let total: usize = parts.iter().map(|p| p.nnz()).sum();
            if total != t.nnz() {
                return Err(format!("n={n}: {total} nnz after partition vs {}", t.nnz()));
            }
            if CooTensor::merge_all(&parts) != t {
                return Err(format!("n={n}: partition union != input"));
            }
            // Theorem 2 balance bound (constant 5 covers multinomial
            // max-deviation slack at these nnz)
            let imb = scratch.push_imbalance();
            let bound = 1.0 + 5.0 * ((n as f64 * (n as f64).ln()) / nnz as f64).sqrt();
            prop_assert(imb <= bound, &format!("n={n}: imbalance {imb} > {bound}"))
        });
    }
}

#[test]
fn prop_zen_balanced_for_any_input_distribution() {
    // Theorem 2 is distribution-free: even adversarially clustered
    // indices must hash into balanced partitions.
    check_seeded(0xd44, 30, |g| {
        let n = 8;
        let dense_len = 200_000;
        // cluster all non-zeros into a random narrow window
        let width = g.usize_in(2_000, 10_000);
        let start = g.usize_in(0, dense_len - width);
        let nnz = g.usize_in(1_000, width.min(4_000));
        let mut idx = g.distinct_sorted_u32(nnz, width as u32);
        for i in idx.iter_mut() {
            *i += start as u32;
        }
        let t = CooTensor::from_sorted(dense_len, idx, vec![1.0; nnz]);
        let h = HierarchicalHasher::with_defaults(g.u64(), n, nnz);
        let out = h.partition(&t);
        let imb = out.push_imbalance();
        let bound = 1.0 + 5.0 * ((n as f64 * (n as f64).ln()) / nnz as f64).sqrt();
        prop_assert(imb <= bound, &format!("imbalance {imb} > {bound}"))
    });
}
