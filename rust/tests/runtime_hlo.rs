//! Integration: the PJRT runtime loads and executes the AOT artifacts,
//! and the numerics match expectations. Requires `make artifacts` and a
//! build with the `xla` feature (the whole file is gated on it — without
//! the feature the runtime is a stub and there is nothing to test here).
#![cfg(feature = "xla")]

use zen::runtime::{lit, Runtime};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn need_artifacts() -> bool {
    let ok = artifacts_dir().join("MANIFEST.txt").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

#[test]
fn murmur_artifact_matches_native() {
    if !need_artifacts() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let exe = rt
        .load_hlo(artifacts_dir().join("murmur_s4_n65536.hlo.txt"))
        .unwrap();
    // Same seeds rust-side.
    let seeds: Vec<u32> = vec![7, 11, 13, 17];
    let n = 65_536usize;
    let indices: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2_654_435_761)).collect();
    let idx_lit = xla::Literal::vec1(&indices);
    let seed_lit = xla::Literal::vec1(&seeds);
    let out = exe.run(&[idx_lit, seed_lit]).unwrap();
    assert_eq!(out.len(), 1);
    let hashes = lit::to_u32(&out[0]).unwrap();
    assert_eq!(hashes.len(), 4 * n);
    // Spot-check against the native rust murmur at random positions.
    for &pos in &[0usize, 1, 1000, 65_535, 70_000, 150_000] {
        let s = pos / n;
        let i = pos % n;
        let expect = zen::hashing::murmur3_32(indices[i], seeds[s]);
        assert_eq!(
            hashes[pos], expect,
            "mismatch at seed {s} idx {i}: jax/pallas vs rust"
        );
    }
}

#[test]
fn train_step_tiny_executes_and_learns() {
    if !need_artifacts() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let exe = rt
        .load_hlo(artifacts_dir().join("train_step_b64_k4_d32_h64.hlo.txt"))
        .unwrap();
    let (b, k, d, h) = (64usize, 4usize, 32usize, 64usize);
    let mut rng = zen::util::Pcg64::seeded(1);
    let mut randn = |n: usize, s: f64| -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * s) as f32).collect()
    };
    let mut center = randn(b * d, 0.5);
    let mut context = randn(b * d, 0.5);
    let mut neg = randn(b * k * d, 0.5);
    let mut w1 = randn(d * h, 0.2);
    let mut b1 = vec![0.0f32; h];
    let mut w2 = randn(h * d, 0.2);
    let mut b2 = vec![0.0f32; d];

    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..10 {
        let out = exe
            .run(&[
                lit::f32(&center, &[b as i64, d as i64]).unwrap(),
                lit::f32(&context, &[b as i64, d as i64]).unwrap(),
                lit::f32(&neg, &[b as i64, k as i64, d as i64]).unwrap(),
                lit::f32(&w1, &[d as i64, h as i64]).unwrap(),
                lit::f32(&b1, &[h as i64]).unwrap(),
                lit::f32(&w2, &[h as i64, d as i64]).unwrap(),
                lit::f32(&b2, &[d as i64]).unwrap(),
            ])
            .unwrap();
        assert_eq!(out.len(), 8);
        let loss = lit::scalar_f32(&out[0]).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        first.get_or_insert(loss);
        last = loss;
        // SGD on every input (fixed batch: loss must fall)
        let lr = 0.1f32;
        let apply = |p: &mut Vec<f32>, g: &xla::Literal| {
            let gv = lit::to_f32(g).unwrap();
            assert_eq!(gv.len(), p.len());
            for (a, b) in p.iter_mut().zip(gv) {
                *a -= lr * b;
            }
        };
        apply(&mut center, &out[1]);
        apply(&mut context, &out[2]);
        apply(&mut neg, &out[3]);
        apply(&mut w1, &out[4]);
        apply(&mut b1, &out[5]);
        apply(&mut w2, &out[6]);
        apply(&mut b2, &out[7]);
    }
    let first = first.unwrap();
    assert!(
        last < first * 0.8,
        "loss should fall on a fixed batch: {first} -> {last}"
    );
}
