//! `zen check` coverage across the real schemes: every entry in
//! [`zen::check::CHECK_SCHEMES`] must survive exhaustive delivery-order
//! exploration at n ∈ {2, 3} — the CI gate in test form — plus a
//! bounded smoke at n = 4 where exhaustion is no longer affordable.

use zen::check::{check_scheme, gen_inputs, replay_schedule, CHECK_SCHEMES, DEFAULT_MAX_RUNS};
use zen::schemes::by_name;
use zen::tensor::CooTensor;

const SEED: u64 = 1;
const EXPECTED_NNZ: usize = 16;

fn inputs(n: usize) -> Vec<CooTensor> {
    gen_inputs(11, n, 48, 5, 3)
}

#[test]
fn every_check_scheme_is_clean_under_exhaustive_exploration() {
    for n in [2usize, 3] {
        let ins = inputs(n);
        for (name, lossless) in CHECK_SCHEMES {
            let scheme = by_name(name, n, SEED, EXPECTED_NNZ)
                .unwrap_or_else(|| panic!("CHECK_SCHEMES entry '{name}' must construct"));
            let r = check_scheme(scheme.as_ref(), &ins, lossless, DEFAULT_MAX_RUNS);
            assert!(
                r.ok(),
                "{name} @ n={n}: {} (replay '{}')",
                r.failure.as_ref().map_or_else(String::new, |f| f.violation.to_string()),
                r.failure.as_ref().map_or_else(String::new, |f| f.replay_arg()),
            );
            assert!(
                !r.stats.truncated,
                "{name} @ n={n}: exploration must be exhaustive within {DEFAULT_MAX_RUNS} runs \
                 (stopped at {})",
                r.stats.runs
            );
            assert!(r.stats.runs >= 1);
            assert!(
                r.output_digest.is_some(),
                "{name} @ n={n}: a clean check always has a canonical digest"
            );
        }
    }
}

#[test]
fn fan_in_schemes_actually_branch() {
    // The gate is only meaningful if exploration visits more than the
    // canonical order for schemes with multi-source fan-in.
    let ins = inputs(3);
    for name in ["sparseps", "agsparse", "zen"] {
        let scheme = by_name(name, 3, SEED, EXPECTED_NNZ).expect("constructs");
        let r = check_scheme(scheme.as_ref(), &ins, true, DEFAULT_MAX_RUNS);
        assert!(r.ok(), "{name}: {:?}", r.failure);
        assert!(
            r.stats.runs > 1 && r.stats.choice_points > 0,
            "{name}: expected delivery branches, got {} runs / {} choice points",
            r.stats.runs,
            r.stats.choice_points
        );
    }
}

#[test]
fn reports_are_deterministic_across_invocations() {
    let ins = inputs(3);
    for name in ["zen", "oktopk"] {
        let scheme = by_name(name, 3, SEED, EXPECTED_NNZ).expect("constructs");
        let a = check_scheme(scheme.as_ref(), &ins, true, DEFAULT_MAX_RUNS);
        let b = check_scheme(scheme.as_ref(), &ins, true, DEFAULT_MAX_RUNS);
        assert_eq!(a.stats, b.stats, "{name}: exploration must be deterministic");
        assert_eq!(a.output_digest, b.output_digest, "{name}");
    }
}

#[test]
fn canonical_replay_matches_the_reference_digest() {
    // The empty schedule replays the canonical order; under the digest
    // the explorer recorded it must come back violation-free for every
    // scheme — the `--replay` round-trip users see.
    let ins = inputs(2);
    for (name, lossless) in CHECK_SCHEMES {
        let scheme = by_name(name, 2, SEED, EXPECTED_NNZ).expect("constructs");
        let r = check_scheme(scheme.as_ref(), &ins, lossless, DEFAULT_MAX_RUNS);
        assert!(r.ok(), "{name}: {:?}", r.failure);
        let (v, record) =
            replay_schedule(scheme.as_ref(), &ins, lossless, r.output_digest, &[]);
        assert!(v.is_none(), "{name}: canonical replay flagged {v:?}");
        assert!(!record.trace.is_empty(), "{name}: a sync must deliver frames");
    }
}

#[test]
fn bounded_exploration_at_n4_stays_clean() {
    // n = 4 state spaces outgrow the exhaustive budget; a truncated
    // sweep is still a valid (bounded) check and must not misreport a
    // violation on a correct scheme.
    let ins = inputs(4);
    let scheme = by_name("zen", 4, SEED, EXPECTED_NNZ).expect("constructs");
    let r = check_scheme(scheme.as_ref(), &ins, true, 50);
    assert!(r.ok(), "{:?}", r.failure);
    assert!(r.stats.runs <= 50);
}
