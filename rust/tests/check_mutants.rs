//! Mutation adequacy for `zen check`: seeded protocol bugs the checker
//! MUST flag. Each mutant is a small all-to-all exchange scheme with
//! one deliberate fault injected at rank 0 — a dropped frame, a
//! duplicated frame, a premature stage park, a misaddressed frame — in
//! two receive styles (counted `NeedFrame` vs aggregate-on-close). A
//! checker that misses any of these is not checking anything; every
//! test also replays the minimized counterexample schedule and demands
//! the same violation kind, so the `--replay` path is exercised on real
//! counterexamples, not just clean runs.

use zen::check::{check_scheme, gen_inputs, replay_schedule, DEFAULT_MAX_RUNS};
use zen::schemes::{
    AggPattern, BalancePattern, CommPattern, PartitionPattern, SchemeDims, SyncScheme,
    SyncScratch,
};
use zen::tensor::CooTensor;
use zen::wire::{Event, Inbox, Message, Protocol, WireError};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Fault {
    /// Control: a correct protocol.
    None,
    /// Rank 0 never sends its frame to the last peer.
    DropLastSend,
    /// Rank 0 sends its frame to the first peer twice.
    DuplicateSend,
    /// Rank 0 sends only one frame and parks on the stage boundary
    /// without waiting for its own inbound frames.
    PrematureDone,
    /// Rank 0 misaddresses the first peer's frame to the second peer.
    WrongDest,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Style {
    /// Receivers count inbound frames (`NeedFrame` until n−1 arrived)
    /// before parking — missing frames become deadlocks.
    Counted,
    /// Receivers park immediately and aggregate whatever the closed
    /// stage delivered — missing/extra frames become wrong sums.
    Closed,
}

/// The (deliberately buggy) scheme under check: one "exchange" stage in
/// which every rank pushes its tensor to every other rank, then every
/// rank completes with the merge of its own tensor and its inbox.
struct MutantScheme {
    style: Style,
    fault: Fault,
}

impl MutantScheme {
    fn new(style: Style, fault: Fault) -> Self {
        MutantScheme { style, fault }
    }
}

/// Rank 0's send list under each fault; other ranks send to every peer
/// in ascending order.
fn send_targets(rank: usize, n: usize, fault: Fault) -> Vec<usize> {
    let peers: Vec<usize> = (0..n).filter(|&p| p != rank).collect();
    if rank != 0 || fault == Fault::None {
        return peers;
    }
    match fault {
        Fault::None => peers,
        Fault::DropLastSend => peers[..peers.len() - 1].to_vec(),
        Fault::DuplicateSend => {
            let mut t = peers.clone();
            t.push(peers[0]);
            t
        }
        Fault::PrematureDone => peers[..1].to_vec(),
        Fault::WrongDest => peers
            .iter()
            .map(|&p| if p == peers[0] { peers[1] } else { p })
            .collect(),
    }
}

impl SyncScheme for MutantScheme {
    fn name(&self) -> &'static str {
        "mutant"
    }

    fn dims(&self) -> SchemeDims {
        SchemeDims {
            communication: CommPattern::PointToPoint,
            aggregation: AggPattern::OneShot,
            partition: PartitionPattern::Centralization,
            balance: BalancePattern::NotApplicable,
            format: "COO",
        }
    }

    fn protocols<'a>(&'a self, inputs: &'a [CooTensor]) -> Vec<Box<dyn Protocol + 'a>> {
        let n = inputs.len();
        (0..n)
            .map(|rank| {
                Box::new(MutantMachine {
                    rank,
                    n,
                    // Rank 0 under PrematureDone parks without counting
                    // its inbound frames even in Counted style.
                    counts: self.style == Style::Counted
                        && !(rank == 0 && self.fault == Fault::PrematureDone),
                    input: inputs[rank].clone(),
                    targets: send_targets(rank, n, self.fault),
                    cursor: 0,
                    inbox: Inbox::new(n),
                    parked: false,
                    out: None,
                }) as Box<dyn Protocol + 'a>
            })
            .collect()
    }
}

struct MutantMachine {
    rank: usize,
    n: usize,
    counts: bool,
    input: CooTensor,
    targets: Vec<usize>,
    cursor: usize,
    inbox: Inbox,
    parked: bool,
    out: Option<CooTensor>,
}

impl Protocol for MutantMachine {
    fn rank(&self) -> usize {
        self.rank
    }

    fn poll(&mut self, _scratch: &mut SyncScratch) -> Result<Event, WireError> {
        if let Some(t) = self.out.take() {
            return Ok(Event::Complete(t));
        }
        if self.cursor < self.targets.len() {
            let dst = self.targets[self.cursor];
            self.cursor += 1;
            return Ok(Event::Send {
                dst,
                msg: Message::PushCoo {
                    from: u32::try_from(self.rank).unwrap(),
                    tensor: self.input.clone(),
                },
            });
        }
        if self.counts && !self.parked && self.inbox.len() < self.n - 1 {
            let src = (0..self.n)
                .find(|&p| p != self.rank && self.inbox.from_src(p) == 0)
                .expect("fewer than n−1 frames yet every peer delivered");
            return Ok(Event::NeedFrame { src });
        }
        self.parked = true;
        Ok(Event::StageDone { name: "exchange" })
    }

    fn deliver(&mut self, src: usize, msg: Message) -> Result<(), WireError> {
        self.inbox.push(src, msg);
        Ok(())
    }

    fn stage_closed(&mut self, name: &str) -> Result<(), WireError> {
        assert_eq!(name, "exchange");
        let mut shards = vec![self.input.clone()];
        for (_, msg) in self.inbox.drain_ascending() {
            match msg {
                Message::PushCoo { tensor, .. } => shards.push(tensor),
                other => panic!("mutant exchange got {other:?}"),
            }
        }
        self.out = Some(CooTensor::merge_all(&shards));
        Ok(())
    }
}

fn inputs(n: usize) -> Vec<CooTensor> {
    gen_inputs(11, n, 48, 5, 3)
}

/// Check a mutant at n = 3, assert the violation kind is one of
/// `expected`, then replay the minimized schedule and demand the same
/// kind again — the counterexample must be self-contained.
fn assert_caught(style: Style, fault: Fault, expected: &[&str]) {
    let ins = inputs(3);
    let scheme = MutantScheme::new(style, fault);
    let report = check_scheme(&scheme, &ins, true, DEFAULT_MAX_RUNS);
    let failure = report.failure.unwrap_or_else(|| {
        panic!("{style:?}+{fault:?}: checker missed the seeded mutant")
    });
    let kind = failure.violation.kind();
    assert!(
        expected.contains(&kind),
        "{style:?}+{fault:?}: caught '{kind}', expected one of {expected:?}"
    );
    // The minimized schedule must reproduce the same violation kind
    // under replay — output-level kinds are re-detected against the
    // canonical digest / oracle, executor-level kinds directly.
    let expect_digest = match kind {
        "output-divergence" => report.output_digest,
        _ => None,
    };
    let (violation, _record) =
        replay_schedule(&scheme, &ins, true, expect_digest, &failure.schedule);
    let replayed = violation.unwrap_or_else(|| {
        panic!("{style:?}+{fault:?}: minimized schedule '{}' replayed clean", failure.replay_arg())
    });
    assert_eq!(
        replayed.kind(),
        kind,
        "{style:?}+{fault:?}: replay of '{}' changed kind",
        failure.replay_arg()
    );
}

/// The control runs must be clean in both styles, or every catch above
/// is meaningless.
#[test]
fn control_mutant_is_clean_in_both_styles() {
    let ins = inputs(3);
    for style in [Style::Counted, Style::Closed] {
        let scheme = MutantScheme::new(style, Fault::None);
        let report = check_scheme(&scheme, &ins, true, DEFAULT_MAX_RUNS);
        assert!(
            report.ok(),
            "{style:?} control flagged: {:?}",
            report.failure
        );
        assert!(!report.stats.truncated, "control must be exhaustive");
        assert!(
            report.stats.runs > 1,
            "all-to-all fan-in must branch (got {} runs)",
            report.stats.runs
        );
    }
}

#[test]
fn counted_drop_last_send_deadlocks() {
    assert_caught(Style::Counted, Fault::DropLastSend, &["deadlock"]);
}

#[test]
fn counted_premature_done_deadlocks() {
    assert_caught(Style::Counted, Fault::PrematureDone, &["deadlock"]);
}

#[test]
fn counted_wrong_dest_deadlocks() {
    assert_caught(Style::Counted, Fault::WrongDest, &["deadlock"]);
}

#[test]
fn counted_duplicate_send_breaks_the_sum() {
    // The duplicated frame inflates rank 1's aggregate; depending on
    // how early the count trips, the canonical order itself may fail
    // the oracle or two orders may diverge.
    assert_caught(
        Style::Counted,
        Fault::DuplicateSend,
        &["oracle-failure", "output-divergence", "completed-with-pending"],
    );
}

#[test]
fn closed_drop_last_send_fails_oracle() {
    assert_caught(Style::Closed, Fault::DropLastSend, &["oracle-failure"]);
}

#[test]
fn closed_duplicate_send_fails_oracle() {
    assert_caught(Style::Closed, Fault::DuplicateSend, &["oracle-failure"]);
}

#[test]
fn closed_premature_done_fails_oracle() {
    assert_caught(Style::Closed, Fault::PrematureDone, &["oracle-failure"]);
}

#[test]
fn closed_wrong_dest_fails_oracle() {
    assert_caught(Style::Closed, Fault::WrongDest, &["oracle-failure"]);
}

#[test]
fn minimized_schedules_are_prefixes() {
    // Minimization scans prefixes from the front, so the schedule it
    // returns is never longer than a full trace of the run — and for
    // the deadlock mutants, where the canonical order itself fails, it
    // is empty (the strongest possible counterexample).
    let ins = inputs(3);
    let scheme = MutantScheme::new(Style::Counted, Fault::DropLastSend);
    let report = check_scheme(&scheme, &ins, true, DEFAULT_MAX_RUNS);
    let failure = report.failure.expect("mutant must be caught");
    assert!(
        failure.schedule.is_empty(),
        "canonical order already deadlocks; got '{}'",
        failure.replay_arg()
    );
}
