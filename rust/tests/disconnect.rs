//! Panic-free protocol paths: a data plane that loses a peer mid-stage
//! must surface [`WireError::Disconnected`] from `run`, not abort the
//! process. The old scheme bodies `expect()`ed every send/recv, so a
//! hung-up channel or closed socket took the whole trainer down; this
//! suite drives every scheme through disconnects injected at every
//! phase of its protocol.

use zen::cluster::{CommReport, LinkKind, Network};
use zen::schemes::{self, SyncScheme, SyncScratch};
use zen::wire::{
    ChannelTransport, FrameRef, Message, SimTransport, Transport, TransportDriver, TransportKind,
    WireError,
};
use zen::workload::random_uniform_inputs;

/// Every scheme variant, by CLI name.
const ALL_SCHEMES: &[&str] = &[
    "dense",
    "agsparse",
    "agsparse-ring",
    "agsparse-hier",
    "sparcml",
    "sparseps",
    "omnireduce",
    "zen",
    "zen-coo",
    "strawman:8",
];

/// A transport that behaves like [`SimTransport`] until the `fail_at`-th
/// operation (send/recv/end_stage), then reports the peer as gone on
/// that and every later call — the deterministic stand-in for a peer
/// crashing at an arbitrary point of the protocol.
struct FailingTransport {
    inner: SimTransport,
    ops: usize,
    fail_at: Option<usize>,
}

impl FailingTransport {
    fn new(net: Network, fail_at: Option<usize>) -> FailingTransport {
        FailingTransport {
            inner: SimTransport::new(net),
            ops: 0,
            fail_at,
        }
    }

    fn tick(&mut self) -> Result<(), WireError> {
        let op = self.ops;
        self.ops += 1;
        match self.fail_at {
            Some(k) if op >= k => Err(WireError::Disconnected),
            _ => Ok(()),
        }
    }
}

impl Transport for FailingTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::Sim
    }

    fn endpoints(&self) -> usize {
        self.inner.endpoints()
    }

    fn send(&mut self, src: usize, dst: usize, frame: FrameRef<'_>) -> Result<(), WireError> {
        self.tick()?;
        self.inner.send(src, dst, frame)
    }

    fn recv(&mut self, dst: usize) -> Result<Message, WireError> {
        self.tick()?;
        self.inner.recv(dst)
    }

    fn end_stage(&mut self, name: &str) -> Result<(), WireError> {
        self.tick()?;
        self.inner.end_stage(name)
    }

    fn take_report(&mut self) -> CommReport {
        self.inner.take_report()
    }
}

#[test]
fn every_scheme_surfaces_disconnect_at_every_protocol_phase() {
    for &machines in &[3usize, 4, 5] {
        let inputs = random_uniform_inputs(0xd15c ^ machines as u64, machines, 3_000, 0.03);
        let nnz = inputs[0].nnz().max(8);
        for name in ALL_SCHEMES {
            let scheme = schemes::by_name(name, machines, 0xd15c, nnz).unwrap();
            let net = Network::new(machines, LinkKind::Tcp25);

            // Count the healthy run's transport operations first.
            let mut probe = FailingTransport::new(net.clone(), None);
            scheme
                .run(
                    &inputs,
                    &mut TransportDriver::over(&mut probe),
                    &mut SyncScratch::new(),
                )
                .unwrap_or_else(|e| panic!("{name} m={machines}: healthy run failed: {e}"));
            let total_ops = probe.ops;
            assert!(total_ops > 0, "{name} m={machines}: no transport traffic");

            // Fail at the first op, the last, and a spread in between —
            // send phases, recv phases, and stage boundaries all get hit.
            let mut points = vec![0, total_ops / 4, total_ops / 2, 3 * total_ops / 4];
            points.push(total_ops - 1);
            points.dedup();
            for k in points {
                let mut tx = FailingTransport::new(net.clone(), Some(k));
                let r = scheme.run(
                    &inputs,
                    &mut TransportDriver::over(&mut tx),
                    &mut SyncScratch::new(),
                );
                match r {
                    Err(WireError::Disconnected) => {}
                    Err(other) => panic!(
                        "{name} m={machines} fail_at={k}/{total_ops}: \
                         expected Disconnected, got {other}"
                    ),
                    Ok(_) => panic!(
                        "{name} m={machines} fail_at={k}/{total_ops}: \
                         sync succeeded over a dead transport"
                    ),
                }
            }
        }
    }
}

#[test]
fn real_channel_hangup_yields_disconnected() {
    // Not an injected error: actually drop one endpoint's channel
    // senders mid-fabric. The first frame that endpoint tries to move
    // must surface the hangup as an Err, not a panic.
    let machines = 4;
    let inputs = random_uniform_inputs(0xc10, machines, 2_000, 0.05);
    for name in ALL_SCHEMES {
        let scheme = schemes::by_name(name, machines, 0xc10, inputs[0].nnz().max(8)).unwrap();
        let net = Network::new(machines, LinkKind::Tcp25);
        let mut ch = ChannelTransport::new(net.clone());
        // Endpoint 2 "crashes" before the sync begins.
        ch.disconnect_endpoint(2);
        let r = scheme.run(
            &inputs,
            &mut TransportDriver::over(&mut ch),
            &mut SyncScratch::new(),
        );
        match r {
            Err(WireError::Disconnected) => {}
            Err(other) => panic!("{name}: expected Disconnected, got {other}"),
            Ok(_) => panic!("{name}: sync succeeded with a hung-up endpoint"),
        }
    }
}

#[test]
fn healthy_channel_unaffected_by_disconnect_api() {
    // disconnect_endpoint on an out-of-range id is a no-op; a healthy
    // fabric still completes.
    let machines = 3;
    let inputs = random_uniform_inputs(0xaa, machines, 1_000, 0.05);
    let scheme = schemes::by_name("zen", machines, 1, inputs[0].nnz().max(8)).unwrap();
    let net = Network::new(machines, LinkKind::Tcp25);
    let mut ch = ChannelTransport::new(net.clone());
    ch.disconnect_endpoint(99);
    let r = scheme
        .run(
            &inputs,
            &mut TransportDriver::over(&mut ch),
            &mut SyncScratch::new(),
        )
        .expect("healthy fabric");
    schemes::verify_outputs(&r, &inputs);
}
