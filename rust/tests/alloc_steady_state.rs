//! Acceptance gate for the scratch-arena layer (ISSUE 2): after warm-up,
//! the partition → hash-bitmap-encode → frame-write → decode pipeline of
//! a repeated workload must perform **zero heap allocations** per
//! iteration — the measured compute charge then reflects the algorithm,
//! not the allocator.
//!
//! PR 7 extends the gate to the discrete-event driver: after a warm-up
//! drive, further simulated stages in totals-only mode must allocate
//! nothing (pooled event slots, retained heap and horizon vectors,
//! in-place stage accounting).
//!
//! Method: a counting `#[global_allocator]` wrapping the system
//! allocator. The tests in this file serialize on one mutex so no
//! sibling test thread can allocate concurrently and pollute the
//! counter. The hasher runs on a single-worker pool: thread spawning
//! allocates by design, and the scoped pool is PR-gated separately for
//! correctness — the zero-allocation claim is about the algorithmic hot
//! path.

// The workspace denies `unsafe_code`; this file is the one sanctioned
// exception — implementing a counting `GlobalAlloc` requires unsafe by
// the trait's own signature.
#![allow(unsafe_code)]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use zen::cluster::{LinkKind, Network};
use zen::hashing::{HashBitmapCodec, HashBitmapPayload, HierarchicalHasher, PartitionScratch};
use zen::schemes::SyncScratch;
use zen::tensor::CooTensor;
use zen::util::{Pcg64, ThreadPool};
use zen::wire::{
    encode_pull_hash_bitmap, encode_push_coo, Driver, Event, EventDriver, Message, Protocol,
    WireError,
};

/// Serializes the tests: the allocation counter is process-global.
static COUNTER_LOCK: Mutex<()> = Mutex::new(());

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocations() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn partition_encode_decode_is_allocation_free_after_warmup() {
    let _guard = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let n = 8;
    let dense_len = 100_000;
    let nnz = 6_000;
    let mut rng = Pcg64::seeded(42);
    let mut idx: Vec<u32> = rng
        .sample_distinct(dense_len, nnz)
        .into_iter()
        .map(|i| i as u32)
        .collect();
    idx.sort_unstable();
    let vals: Vec<f32> = (0..nnz).map(|_| rng.next_f32() + 0.01).collect();
    let t = CooTensor::from_sorted(dense_len, idx, vals);

    let hasher = HierarchicalHasher::with_defaults(7, n, t.nnz())
        .with_pool(ThreadPool::with_workers(1));
    let domains = hasher.partition_domains(dense_len);
    let codecs: Vec<HashBitmapCodec> = domains.iter().map(|d| HashBitmapCodec::new(d)).collect();

    let mut scratch = PartitionScratch::new();
    let mut payload = HashBitmapPayload::default();
    let mut dec_idx: Vec<u32> = Vec::new();
    let mut dec_val: Vec<f32> = Vec::new();
    let mut frame: Vec<u8> = Vec::new();

    let iteration = |scratch: &mut PartitionScratch,
                         payload: &mut HashBitmapPayload,
                         dec_idx: &mut Vec<u32>,
                         dec_val: &mut Vec<f32>,
                         frame: &mut Vec<u8>| {
        hasher.partition_into(&t, scratch);
        frame.clear();
        let mut decoded = 0usize;
        for (p, codec) in codecs.iter().enumerate() {
            let part = scratch.part(p);
            encode_push_coo(0, part.dense_len, part.indices, part.values, frame);
            codec.encode_into(part, payload);
            encode_pull_hash_bitmap(p as u32, &payload.bitmap, &payload.values, frame);
            codec.decode_into(payload, dec_idx, dec_val);
            decoded += dec_idx.len();
        }
        decoded
    };

    // Warm-up: buffers grow to steady-state capacity, domains exist.
    let mut warm_total = 0;
    for _ in 0..3 {
        warm_total = iteration(
            &mut scratch,
            &mut payload,
            &mut dec_idx,
            &mut dec_val,
            &mut frame,
        );
    }
    assert_eq!(warm_total, t.nnz(), "pipeline must be lossless");

    // Steady state: zero heap allocations across 10 full iterations.
    let before = allocations();
    let mut total = 0;
    for _ in 0..10 {
        total += iteration(
            &mut scratch,
            &mut payload,
            &mut dec_idx,
            &mut dec_val,
            &mut frame,
        );
    }
    let after = allocations();
    assert_eq!(total, 10 * t.nnz());
    assert_eq!(
        after - before,
        0,
        "partition→encode→decode steady state must not allocate"
    );
}

// ---- event-driver steady state (PR 7) ------------------------------

/// Barrier-frame toy protocol: each of `rounds` stages, every rank
/// sends one empty COO frame (`CooTensor::empty` holds no heap memory)
/// to the next rank, waits for one frame, parks. Exercises the full
/// schedule → heap → deliver → stage-close loop without any payload
/// allocations of its own.
struct Pulse {
    rank: usize,
    n: usize,
    rounds: usize,
    round: usize,
    sent: bool,
    got: bool,
}

impl Pulse {
    fn machines(n: usize, rounds: usize) -> Vec<Box<dyn Protocol>> {
        (0..n)
            .map(|rank| {
                Box::new(Pulse {
                    rank,
                    n,
                    rounds,
                    round: 0,
                    sent: false,
                    got: false,
                }) as Box<dyn Protocol>
            })
            .collect()
    }
}

impl Protocol for Pulse {
    fn rank(&self) -> usize {
        self.rank
    }

    fn poll(&mut self, _scratch: &mut SyncScratch) -> Result<Event, WireError> {
        if self.round == self.rounds {
            return Ok(Event::Complete(CooTensor::empty(8)));
        }
        if !self.sent {
            self.sent = true;
            return Ok(Event::Send {
                dst: (self.rank + 1) % self.n,
                msg: Message::PushCoo {
                    from: self.rank as u32,
                    tensor: CooTensor::empty(8),
                },
            });
        }
        if !self.got {
            return Ok(Event::NeedFrame {
                src: (self.rank + self.n - 1) % self.n,
            });
        }
        Ok(Event::StageDone { name: "pulse" })
    }

    fn deliver(&mut self, _src: usize, _msg: Message) -> Result<(), WireError> {
        self.got = true;
        Ok(())
    }

    fn stage_closed(&mut self, _name: &str) -> Result<(), WireError> {
        self.round += 1;
        self.sent = false;
        self.got = false;
        Ok(())
    }
}

/// Allocations of one totals-only drive over `rounds` barrier stages
/// (including boxing the machines — a per-drive constant).
fn event_drive_allocs(rounds: usize) -> usize {
    let n = 8;
    let net = Network::new(n, LinkKind::Tcp25);
    let mut drv = EventDriver::new(net).totals_only();
    let mut scratch = SyncScratch::new();
    let before = allocations();
    let out = drv
        .drive(Pulse::machines(n, rounds), &mut scratch)
        .expect("pulse drive");
    let after = allocations();
    assert_eq!(out.outputs.len(), n);
    assert_eq!(drv.totals().stages as usize, rounds);
    assert_eq!(drv.events_processed() as usize, n * rounds);
    assert!(drv.pool_high_water() <= n, "≤ one in-flight frame per rank");
    after - before
}

#[test]
fn event_driver_totals_mode_is_allocation_free_per_stage() {
    let _guard = COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // Per-drive constants (machine boxes, first-round pool/heap growth)
    // are identical for both drives, so 100 extra simulated stages must
    // cost exactly zero additional allocations.
    let short = event_drive_allocs(5);
    let long = event_drive_allocs(105);
    assert_eq!(
        long, short,
        "event-driver steady state must not allocate per stage"
    );
}
