//! Lossy-compression integration (PR 9): the error-feedback contract
//! as a bit-level property, across compressors and iterations.
//!
//! The compressor's invariant is *conservation*, not approximation:
//! selection partitions the merged accumulator (previous residual +
//! new gradient) without any arithmetic at the split, so over any
//! horizon T
//!
//!     Σ_t sent_t  +  residual_T  ==  Σ_t grad_t
//!
//! exactly — bit-for-bit when every gradient value is an exact binary
//! fraction, because then every f32 addition along both sides is
//! exact. This suite drives T iterations of quantized gradients
//! (multiples of 2⁻¹⁰, bounded numerators) through Top-k and
//! Threshold and compares dense accumulations bitwise.

use zen::compress::{compress_all, CompressSpec, Compressor, Threshold, TopK};
use zen::tensor::CooTensor;
use zen::util::Pcg64;

const DENSE_LEN: usize = 2_048;

/// Random sparse gradients whose values are non-zero multiples of
/// 2⁻¹⁰ with small integer numerators — every partial sum the
/// compressor or the test can form stays exactly representable in f32.
fn quantized_inputs(seed: u64, n: usize, density: f64) -> Vec<CooTensor> {
    let nnz = ((DENSE_LEN as f64 * density) as usize).max(1);
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| {
            let mut idx: Vec<u32> = rng
                .sample_distinct(DENSE_LEN, nnz)
                .into_iter()
                .map(|i| i as u32)
                .collect();
            idx.sort_unstable();
            let vals: Vec<f32> = idx
                .iter()
                .map(|_| {
                    // numerator in [-1024, 1024] \ {0}
                    let num = (rng.below(2048) as i64) - 1024;
                    let num = if num == 0 { 7 } else { num };
                    num as f32 * (1.0 / 1024.0)
                })
                .collect();
            CooTensor::from_sorted(DENSE_LEN, idx, vals)
        })
        .collect()
}

/// Dense-accumulate a COO tensor into `acc` (exact adds by input
/// construction).
fn add_into(acc: &mut [f32], t: &CooTensor) {
    for (&i, &v) in t.indices.iter().zip(t.values.iter()) {
        acc[i as usize] += v;
    }
}

fn assert_bitwise_equal(lhs: &[f32], rhs: &[f32], ctx: &str) {
    for (i, (a, b)) in lhs.iter().zip(rhs.iter()).enumerate() {
        // Exact-zero results may legitimately differ in sign bit
        // (the compressor prunes exactly-cancelled entries; the test
        // accumulator keeps +0.0) — everything else must match
        // bit-for-bit.
        let ok = a.to_bits() == b.to_bits() || (*a == 0.0 && *b == 0.0);
        assert!(
            ok,
            "{ctx}: index {i}: {a} ({:08x}) vs {b} ({:08x})",
            a.to_bits(),
            b.to_bits()
        );
    }
}

/// T iterations through a compressor; assert per-rank conservation
/// against the residual exposed by `residual_of`.
fn conservation_property<C, R>(mut comp: C, residual_of: R, seed: u64, iters: u64, n: usize)
where
    C: Compressor,
    R: Fn(&C, usize) -> CooTensor,
{
    let mut total_grad = vec![vec![0f32; DENSE_LEN]; n];
    let mut total_sent = vec![vec![0f32; DENSE_LEN]; n];
    let mut ever_dropped = false;
    for t in 0..iters {
        let grads = quantized_inputs(seed.wrapping_add(t.wrapping_mul(0x9e37)), n, 0.05);
        for (rank, g) in grads.iter().enumerate() {
            let sent = comp.compress("emb", rank, g);
            add_into(&mut total_grad[rank], g);
            add_into(&mut total_sent[rank], &sent);
            ever_dropped |= sent.nnz() < g.nnz();
        }
    }
    assert!(
        ever_dropped,
        "{}: the compressor never dropped anything",
        comp.name()
    );
    let stats = comp.stats();
    assert!(stats.sent_entries < stats.raw_entries, "stats must record the drop");
    assert!(stats.bytes_saved() > 0);
    assert_eq!(
        stats.bytes_saved(),
        (stats.raw_entries - stats.sent_entries) * 8,
        "one COO entry is 8 wire bytes"
    );
    for rank in 0..n {
        let mut got = total_sent[rank].clone();
        add_into(&mut got, &residual_of(&comp, rank));
        assert_bitwise_equal(
            &got,
            &total_grad[rank],
            &format!("{} rank {rank}: sent + residual != grads", comp.name()),
        );
    }
}

#[test]
fn topk_error_feedback_conserves_gradient_mass_bitwise() {
    conservation_property(
        TopK::new(0.02),
        |c, rank| c.feedback().residual("emb", rank, DENSE_LEN),
        0x7e57_0001,
        12,
        4,
    );
}

#[test]
fn threshold_error_feedback_conserves_gradient_mass_bitwise() {
    conservation_property(
        Threshold::new(0.25),
        |c, rank| c.feedback().residual("emb", rank, DENSE_LEN),
        0x7e57_0002,
        12,
        4,
    );
}

#[test]
fn compressed_sync_is_lossless_over_the_compressed_tensors() {
    // The lossy error lives entirely in the residuals: the collective
    // itself must reproduce the sum of the compressed tensors exactly,
    // for every scheme, Ok-Topk included.
    use zen::cluster::{LinkKind, Network};
    use zen::schemes::{self, SyncScheme, SyncScratch};
    let n = 4;
    let raw = quantized_inputs(0xabcd, n, 0.06);
    let mut comp = CompressSpec::TopK(0.01).build().unwrap();
    let inputs = compress_all(comp.as_mut(), "emb", &raw);
    assert!(inputs.iter().zip(raw.iter()).all(|(c, r)| c.nnz() < r.nnz()));
    let net = Network::new(n, LinkKind::Tcp25);
    for name in ["zen", "zen-coo", "oktopk", "sparseps", "omnireduce", "allreduce"] {
        let scheme = schemes::by_name(name, n, 0x5eed, inputs[0].nnz().max(8)).unwrap();
        let r = scheme.run_sim(&inputs, &net, &mut SyncScratch::new());
        schemes::verify_outputs(&r, &inputs);
    }
}

#[test]
fn compression_reaches_five_x_at_one_percent_topk() {
    // The acceptance ratio: k = 1% of the dense length on ~6%-dense
    // gradients must cut wire entries by at least 5× — including in
    // steady state, where the residual keeps re-offering unsent mass.
    let n = 8;
    let mut comp = TopK::new(0.01);
    let mut raw_entries = 0u64;
    let mut sent_entries = 0u64;
    for t in 0..8u64 {
        let grads = quantized_inputs(0xfee1 ^ t, n, 0.06);
        let sent = compress_all(&mut comp, "emb", &grads);
        raw_entries += grads.iter().map(|g| g.nnz() as u64).sum::<u64>();
        sent_entries += sent.iter().map(|s| s.nnz() as u64).sum::<u64>();
    }
    assert!(
        sent_entries * 5 <= raw_entries,
        "top-k at 1% only reached {raw_entries}/{sent_entries} reduction"
    );
}
