//! Empty-gradient property suite: every scheme × every transport must
//! survive all-zero (`nnz = 0`) inputs — the frozen-layer / warm-up /
//! sparsified-to-nothing edge every real training run eventually hits.
//!
//! Contract per (scheme, machines, case):
//! - the synchronization completes (no panic, no protocol stall),
//! - outputs are lossless: every endpoint's aggregate equals the dense
//!   reference sum (all-zero when every input is empty),
//! - byte accounting is consistent: sim and channel backends report
//!   identical per-stage sent/recv vectors, and outputs are
//!   bit-identical across backends (socket-mesh smoke-checked where
//!   sockets are permitted).

use zen::cluster::{LinkKind, Network};
use zen::schemes::{self, SyncScheme, SyncScratch};
use zen::tensor::CooTensor;
use zen::util::Pcg64;
use zen::wire::{ChannelTransport, SocketDriver, TransportDriver};

const DENSE_LEN: usize = 4_096;

/// Every scheme name, lossy strawman included (with nothing to lose,
/// even it must round-trip exactly).
const ALL_SCHEMES: &[&str] = &[
    "dense",
    "agsparse",
    "agsparse-ring",
    "agsparse-hier",
    "sparcml",
    "sparseps",
    "omnireduce",
    "zen",
    "zen-coo",
    "oktopk",
    "strawman:8",
];

fn all_empty(n: usize) -> Vec<CooTensor> {
    vec![CooTensor::empty(DENSE_LEN); n]
}

/// Worker 0 contributes nothing; the rest contribute random non-zeros.
fn one_empty(seed: u64, n: usize) -> Vec<CooTensor> {
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|w| {
            if w == 0 {
                return CooTensor::empty(DENSE_LEN);
            }
            let nnz = 64 + rng.below(64) as usize;
            let mut idx: Vec<u32> = rng
                .sample_distinct(DENSE_LEN, nnz)
                .into_iter()
                .map(|i| i as u32)
                .collect();
            idx.sort_unstable();
            let vals: Vec<f32> = (0..nnz).map(|_| rng.next_f32() + 0.125).collect();
            CooTensor::from_sorted(DENSE_LEN, idx, vals)
        })
        .collect()
}

/// Run one scheme over sim and channel; assert losslessness and
/// stage-exact byte consistency between the backends.
fn check_cell(name: &str, inputs: &[CooTensor], lossless_expected: bool) {
    let n = inputs.len();
    let scheme = schemes::by_name(name, n, 0xe1, 128).unwrap();
    let net = Network::new(n, LinkKind::Tcp25);
    let ctx = format!("{name} m={n}");

    let sim = scheme.run_sim(inputs, &net, &mut SyncScratch::new());
    let mut ch = ChannelTransport::new(net.clone());
    let mut drv = TransportDriver::over(&mut ch);
    let chan = scheme
        .run(inputs, &mut drv, &mut SyncScratch::new())
        .unwrap_or_else(|e| panic!("{ctx}: channel sync failed: {e}"));

    // Byte consistency: the two data planes must observe the same
    // traffic, stage by stage, empty frames included.
    assert_eq!(
        sim.report.stages.len(),
        chan.report.stages.len(),
        "{ctx}: stage count"
    );
    for (s, c) in sim.report.stages.iter().zip(chan.report.stages.iter()) {
        assert_eq!(s.sent, c.sent, "{ctx}: stage '{}' sent", s.name);
        assert_eq!(s.recv, c.recv, "{ctx}: stage '{}' recv", s.name);
    }
    assert_eq!(
        sim.report.total_bytes(),
        chan.report.total_bytes(),
        "{ctx}: total bytes"
    );

    // Outputs: bit-identical across backends, lossless vs the dense
    // reference (strawman only where there is nothing to lose).
    assert_eq!(sim.outputs.len(), chan.outputs.len(), "{ctx}");
    for (a, b) in sim.outputs.iter().zip(chan.outputs.iter()) {
        assert_eq!(a, b, "{ctx}: outputs diverge across backends");
    }
    if lossless_expected {
        schemes::verify_outputs(&chan, inputs);
    }
}

#[test]
fn all_workers_empty_every_scheme_every_machine_count() {
    // n = 5 exercises SparCML's non-power-of-two fold path with empty
    // payloads as well.
    for n in [2usize, 4, 5] {
        for name in ALL_SCHEMES {
            check_cell(name, &all_empty(n), true);
        }
    }
}

#[test]
fn all_empty_aggregate_is_exactly_zero() {
    for name in ALL_SCHEMES {
        let inputs = all_empty(3);
        let scheme = schemes::by_name(name, 3, 0xe2, 128).unwrap();
        let net = Network::new(3, LinkKind::Tcp25);
        let r = scheme.run_sim(&inputs, &net, &mut SyncScratch::new());
        for (e, out) in r.outputs.iter().enumerate() {
            assert_eq!(out.dense_len, DENSE_LEN, "{name}: endpoint {e} range");
            assert!(
                out.values.iter().all(|&v| v == 0.0),
                "{name}: endpoint {e} must hold an all-zero aggregate"
            );
        }
    }
}

#[test]
fn one_empty_worker_every_scheme() {
    // A single frozen worker among active ones: the aggregate must still
    // be exact. The lossy strawman is excluded from the reference check
    // (collisions may drop real gradients by design) but must still be
    // byte-consistent across backends.
    for n in [2usize, 4, 5] {
        for name in ALL_SCHEMES {
            let inputs = one_empty(0x10e ^ n as u64, n);
            check_cell(name, &inputs, !name.starts_with("strawman"));
        }
    }
}

/// PR 9 degenerate-k hardening, riding the same grid: `topk:0` must
/// turn every gradient into the all-empty case above (zero entries on
/// the wire, everything in the residual), and a k ≥ nnz Top-k must be
/// bit-identical lossless — the compressor degrades to a pass-through
/// and no scheme may notice it ran.
#[test]
fn degenerate_topk_rides_the_empty_gradient_grid() {
    use zen::compress::{compress_all, CompressSpec};
    for n in [2usize, 4, 5] {
        let raw = one_empty(0x70b ^ n as u64, n);

        // k = 0: every compressed tensor is empty; the full grid must
        // behave exactly like the all-empty case.
        let mut zero = CompressSpec::TopK(0.0).build().unwrap();
        let zeroed = compress_all(zero.as_mut(), "g", &raw);
        assert!(zeroed.iter().all(|t| t.nnz() == 0), "topk:0 must send nothing");
        for name in ALL_SCHEMES {
            check_cell(name, &zeroed, true);
        }

        // k ≥ nnz (density 1.0 → k = dense_len): bit-identical
        // pass-through, residuals stay empty.
        let mut full = zen::compress::TopK::new(1.0);
        let passed = compress_all(&mut full, "g", &raw);
        assert_eq!(passed, raw, "k >= nnz must be bit-identical lossless");
        for (rank, t) in raw.iter().enumerate() {
            let resid = full.feedback().residual("g", rank, t.dense_len);
            assert_eq!(resid.nnz(), 0, "rank {rank}: lossless pass left a residual");
        }
        for name in ALL_SCHEMES {
            check_cell(name, &passed, !name.starts_with("strawman"));
        }
    }
}

#[test]
fn empty_inputs_over_socket_smoke() {
    // Real loopback sockets moving zero-payload frames: header-only
    // traffic must flow and account identically to the simulator.
    let n = 3;
    let inputs = all_empty(n);
    let net = Network::new(n, LinkKind::Tcp25);
    for name in ["zen", "sparseps", "dense"] {
        let scheme = schemes::by_name(name, n, 0xe3, 128).unwrap();
        let sim = scheme.run_sim(&inputs, &net, &mut SyncScratch::new());
        let mut sock = match SocketDriver::mesh(net.clone()) {
            Ok(t) => t,
            Err(e) => {
                // Sandboxes may forbid loopback sockets; channel parity
                // above already covers the encode/decode path.
                eprintln!("skipping socket empty-gradient smoke ({name}): {e}");
                return;
            }
        };
        let real = scheme
            .run(&inputs, &mut sock, &mut SyncScratch::new())
            .unwrap_or_else(|e| panic!("{name}: socket sync failed: {e}"));
        for (s, c) in sim.report.stages.iter().zip(real.report.stages.iter()) {
            assert_eq!(s.sent, c.sent, "{name}: socket stage '{}' sent", s.name);
            assert_eq!(s.recv, c.recv, "{name}: socket stage '{}' recv", s.name);
        }
        assert_eq!(sim.outputs, real.outputs, "{name}: socket outputs diverge");
        schemes::verify_outputs(&real, &inputs);
    }
}
