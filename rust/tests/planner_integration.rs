//! Planner integration: crossover correctness and measurement caching.
//!
//! Crossover correctness is the planner's contract: on a grid of
//! synthetic densities × machine counts, the scheme the cost model
//! ranks first must be (close to) the scheme with the smallest
//! *transport-observed* communication time. "Close to" tolerates
//! near-ties — at some grid cells two schemes are within a few percent
//! and header-level effects decide the measured order — but a planner
//! that picks a scheme measurably slower than the best by more than
//! the tie margin fails.

use zen::cluster::{LinkKind, Network, Topology};
use zen::planner::{plan_bucket, CostPlanner, MeasuredStats, PlanConfig, Planner};
use zen::schemes::{self, CommPattern, SyncScheme, SyncScratch, PLANNER_CANDIDATES};
use zen::tensor::block::DEFAULT_BLOCK;
use zen::wire::EventDriver;
use zen::workload::{group_clustered_inputs, random_uniform_inputs};

/// Transport-observed comm time of one candidate on `inputs`.
fn measured_time(name: &str, inputs: &[zen::tensor::CooTensor], net: &Network) -> f64 {
    let n = inputs.len();
    let nnz = inputs.iter().map(|t| t.nnz()).max().unwrap_or(1).max(1);
    let scheme = schemes::by_name(name, n, 0x5eed, nnz).unwrap();
    let r = scheme.run_sim(inputs, net, &mut SyncScratch::new());
    r.report.comm_time()
}

#[test]
fn cost_model_argmin_tracks_transport_measured_best() {
    let dense_len = 1 << 14;
    let link = LinkKind::Tcp25;
    let cfg = PlanConfig::default();
    for machines in [2usize, 4, 8] {
        for density in [0.002f64, 0.02, 0.15] {
            let inputs =
                random_uniform_inputs(0xc405 ^ machines as u64, machines, dense_len, density);
            let stats = MeasuredStats::from_tensors(&inputs, &[machines], &[DEFAULT_BLOCK]);
            let topo = Topology::flat(machines, link);
            let plan = plan_bucket("cell", dense_len as f64, machines, &topo, &cfg, stats);

            let net = Network::new(machines, link);
            let measured: Vec<(&str, f64)> = PLANNER_CANDIDATES
                .iter()
                .map(|&name| (name, measured_time(name, &inputs, &net)))
                .collect();
            let (best_name, best_time) = measured
                .iter()
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .copied()
                .unwrap();
            let (_, chosen_time) = measured
                .iter()
                .find(|(name, _)| *name == plan.chosen)
                .copied()
                .unwrap();
            assert!(
                chosen_time <= best_time * 1.35,
                "n={machines} d={density}: planner chose {} ({chosen_time:.2e}s), \
                 measured best is {best_name} ({best_time:.2e}s) — beyond tie margin.\n\
                 ranked: {:?}",
                plan.chosen,
                plan.costs
                    .iter()
                    .map(|c| (c.scheme, c.time))
                    .collect::<Vec<_>>()
            );
        }
    }
}

#[test]
fn non_power_of_two_machines_plan_without_panic() {
    // The old CostModel::sparcml asserted 2^k nodes; the planner must
    // rank every candidate at n = 6 (and the choice must execute).
    let machines = 6;
    let inputs = random_uniform_inputs(0x6666, machines, 1 << 13, 0.02);
    let planner = CostPlanner::new(machines, 0x5eed, 256, PlanConfig::default());
    let planned = planner.plan("n6", &inputs, &Topology::flat(machines, LinkKind::Tcp25));
    let plan = planned.plan.expect("auto always plans");
    assert_eq!(plan.costs.len(), PLANNER_CANDIDATES.len());
    assert!(plan.costs.iter().all(|c| c.time.is_finite()));
    let net = Network::new(machines, LinkKind::Tcp25);
    let r = planned
        .scheme
        .run_sim(&inputs, &net, &mut SyncScratch::new());
    schemes::verify_outputs(&r, &inputs);
}

#[test]
fn repeated_profiling_returns_identical_stats() {
    // MeasuredStats caching contract, both halves: (1) profiling the
    // same tensors twice yields value-identical stats; (2) the planner
    // serves the *same* cached stats object across iterations instead
    // of re-profiling.
    let inputs = random_uniform_inputs(0xcace, 4, 1 << 13, 0.03);
    let a = MeasuredStats::from_tensors(&inputs, &[4], &[DEFAULT_BLOCK]);
    let b = MeasuredStats::from_tensors(&inputs, &[4], &[DEFAULT_BLOCK]);
    assert_eq!(a, b, "profiling is deterministic");

    let planner = CostPlanner::new(4, 0x5eed, 256, PlanConfig::default());
    let tcp = Topology::flat(4, LinkKind::Tcp25);
    let first = planner.plan("bucket", &inputs, &tcp).plan.unwrap();
    let second = planner.plan("bucket", &inputs, &tcp).plan.unwrap();
    assert!(
        std::sync::Arc::ptr_eq(&first, &second),
        "cached plan (and its stats) must be the same object"
    );
    assert_eq!(first.stats, a, "cached stats equal a fresh profile");
    assert_eq!(planner.profile_count(), 1, "no re-profiling at steady state");
}

#[test]
fn plan_bucket_validates_at_large_n_on_parsed_topologies() {
    // The planner's cost tables must stay finite and complete at event-
    // driver scale: n ∈ {64, 256, 1024} ranks placed by parsed 3-tier
    // (rank/node/fabric) topology specs.
    let dense_len = 1 << 13;
    let cfg = PlanConfig::default();
    for (spec, machines) in [
        ("8x8:2,300/50,25", 64usize),
        ("16x16:2,300/50,25", 256),
        ("32x32:2,300/50,25", 1024),
    ] {
        let topo = Topology::parse(spec, LinkKind::Tcp25).unwrap();
        assert_eq!(topo.endpoints(), machines, "{spec}");
        let inputs = random_uniform_inputs(0xb16 ^ machines as u64, machines, dense_len, 0.005);
        let stats = MeasuredStats::from_tensors(&inputs, &[machines], &[DEFAULT_BLOCK]);
        let plan = plan_bucket("cell", dense_len as f64, machines, &topo, &cfg, stats);
        assert_eq!(
            plan.costs.len(),
            PLANNER_CANDIDATES.len(),
            "{spec}: every candidate ranked"
        );
        assert!(
            plan.costs.iter().all(|c| c.time.is_finite()),
            "{spec}: finite costs"
        );
        assert!(
            schemes::by_name(plan.chosen, machines, 0x5eed, 64).is_some(),
            "{spec}: chosen scheme '{}' must construct at n={machines}",
            plan.chosen
        );
    }
}

#[test]
fn auto_at_1024_ranks_completes_on_the_event_driver() {
    // PR 7 acceptance: `--scheme auto` at n = 1024 on a two-level
    // 32×32 fabric with 10× slower inter-node links completes under
    // the single-threaded event driver, and the placement flips the
    // argmin to a hierarchical scheme where the flat mesh would not
    // pick one (the n=8 flip of tests/topology_integration.rs, at
    // event-driver scale).
    let n = 1024usize;
    let (nodes, ranks) = (32usize, 32usize);
    let dense_len = 4096;
    // Group-clustered sparsity aligned with the placement: one group
    // per node.
    let inputs = group_clustered_inputs(0x1024, nodes, ranks, dense_len, 0.005);
    let two_level = Topology::parse("32x32:0,250/0,25", LinkKind::Tcp25).unwrap();
    let flat = Topology::flat(n, LinkKind::Custom(25_000_000_000, 0));

    let comm_pattern = |name: &str| {
        schemes::by_name(name, n, 1, 64)
            .unwrap_or_else(|| panic!("chosen scheme '{name}' must construct"))
            .dims()
            .communication
    };
    let flat_planner = CostPlanner::new(n, 0x5eed, 64, PlanConfig::default());
    let flat_chosen = flat_planner
        .plan("bucket", &inputs, &flat)
        .plan
        .unwrap()
        .chosen;
    let topo_planner = CostPlanner::new(n, 0x5eed, 64, PlanConfig::default());
    let planned = topo_planner.plan("bucket", &inputs, &two_level);
    let topo_chosen = planned.plan.as_ref().unwrap().chosen;
    assert_ne!(
        comm_pattern(flat_chosen),
        CommPattern::Hierarchy,
        "flat mesh must not pick a hierarchical scheme here (picked {flat_chosen})"
    );
    assert_eq!(
        comm_pattern(topo_chosen),
        CommPattern::Hierarchy,
        "32x32 with 10x slower inter links must pick a hierarchical scheme \
         (picked {topo_chosen}; flat picked {flat_chosen})"
    );

    // Execute the choice once at full scale, on one thread.
    let net = Network::with_topology(two_level);
    let mut drv = EventDriver::new(net);
    let r = planned
        .scheme
        .run(&inputs, &mut drv, &mut SyncScratch::new())
        .expect("1024-rank event-driver sync");
    schemes::verify_outputs(&r, &inputs);
    assert_eq!(
        drv.virtual_time(),
        r.report.comm_time(),
        "event clock == report comm time at n=1024"
    );
    assert!(drv.events_processed() > 0);
}

#[test]
fn lossy_tier_only_fires_when_compression_beats_lossless() {
    // PR 9 acceptance pin: an armed planner adopts the lossy tier only
    // where the predicted post-compression volume beats the best
    // lossless plan — and executing the choice on the compressed
    // tensors is measurably cheaper than the lossless argmin on the
    // raw ones. A pass-through compressor must never flip the plan.
    use zen::compress::{compress_all, CompressSpec};
    let machines = 8;
    let dense_len = 1 << 16;
    let link = LinkKind::Tcp25;
    let topo = Topology::flat(machines, link);
    let inputs = random_uniform_inputs(0x9a55, machines, dense_len, 0.03);
    let cfg = PlanConfig {
        compress: CompressSpec::TopK(0.001),
        accuracy_budget: 0.05,
        ..PlanConfig::default()
    };
    let planner = CostPlanner::new(machines, 0x5eed, 256, cfg.clone());
    let planned = planner.plan("emb", &inputs, &topo);
    let plan = planned.plan.expect("auto always plans");
    assert!(plan.lossy, "3% -> 0.1% density must arm the lossy tier");
    assert!(plan.predicted_lossy_time.unwrap() < plan.predicted_lossless_time);
    assert_eq!(plan.compressor.as_deref(), Some("topk:0.001"));

    // Transport-observed comparison: the lossy choice on compressed
    // tensors vs the lossless argmin on the raw ones.
    let net = Network::new(machines, link);
    let mut comp = cfg.compress.build().unwrap();
    let compressed = compress_all(comp.as_mut(), "emb", &inputs);
    let lossy_run = planned
        .scheme
        .run_sim(&compressed, &net, &mut SyncScratch::new());
    schemes::verify_outputs(&lossy_run, &compressed);
    let lossless = plan_bucket(
        "emb",
        dense_len as f64,
        machines,
        &topo,
        &PlanConfig::default(),
        MeasuredStats::from_tensors(&inputs, &[machines], &[DEFAULT_BLOCK]),
    );
    let base_time = measured_time(lossless.chosen, &inputs, &net);
    assert!(
        lossy_run.report.comm_time() < base_time,
        "compressed sync ({:.2e}s) not cheaper than lossless {} ({base_time:.2e}s)",
        lossy_run.report.comm_time(),
        lossless.chosen,
    );

    // Degenerate: a compressor that keeps everything prices identically
    // to the lossless table and the strict comparison must hold it off.
    let cfg_pass = PlanConfig {
        compress: CompressSpec::TopK(1.0),
        accuracy_budget: 0.05,
        ..PlanConfig::default()
    };
    let p2 = CostPlanner::new(machines, 0x5eed, 256, cfg_pass);
    let plan2 = p2.plan("emb", &inputs, &topo).plan.unwrap();
    assert!(!plan2.lossy, "a pass-through compressor must never win");
    assert!(plan2.predicted_lossy_time.unwrap() >= plan2.predicted_lossless_time);
}

#[test]
fn hysteresis_zero_replans_on_any_drift() {
    let cfg = PlanConfig {
        replan_threshold: 0.0,
        ..PlanConfig::default()
    };
    let planner = CostPlanner::new(4, 0x5eed, 256, cfg);
    let tcp = Topology::flat(4, LinkKind::Tcp25);
    planner.plan("b", &random_uniform_inputs(1, 4, 4096, 0.020), &tcp);
    // ~10% denser: outside a zero threshold, inside the default 0.25
    planner.plan("b", &random_uniform_inputs(2, 4, 4096, 0.022), &tcp);
    assert_eq!(planner.profile_count(), 2, "zero hysteresis re-plans");
}
