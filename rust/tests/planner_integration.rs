//! Planner integration: crossover correctness and measurement caching.
//!
//! Crossover correctness is the planner's contract: on a grid of
//! synthetic densities × machine counts, the scheme the cost model
//! ranks first must be (close to) the scheme with the smallest
//! *transport-observed* communication time. "Close to" tolerates
//! near-ties — at some grid cells two schemes are within a few percent
//! and header-level effects decide the measured order — but a planner
//! that picks a scheme measurably slower than the best by more than
//! the tie margin fails.

use zen::cluster::{LinkKind, Network, Topology};
use zen::planner::{plan_bucket, CostPlanner, MeasuredStats, PlanConfig, Planner};
use zen::schemes::{self, SyncScheme, SyncScratch, PLANNER_CANDIDATES};
use zen::tensor::block::DEFAULT_BLOCK;
use zen::workload::random_uniform_inputs;

/// Transport-observed comm time of one candidate on `inputs`.
fn measured_time(name: &str, inputs: &[zen::tensor::CooTensor], net: &Network) -> f64 {
    let n = inputs.len();
    let nnz = inputs.iter().map(|t| t.nnz()).max().unwrap_or(1).max(1);
    let scheme = schemes::by_name(name, n, 0x5eed, nnz).unwrap();
    let r = scheme.run_sim(inputs, net, &mut SyncScratch::new());
    r.report.comm_time()
}

#[test]
fn cost_model_argmin_tracks_transport_measured_best() {
    let dense_len = 1 << 14;
    let link = LinkKind::Tcp25;
    let cfg = PlanConfig::default();
    for machines in [2usize, 4, 8] {
        for density in [0.002f64, 0.02, 0.15] {
            let inputs =
                random_uniform_inputs(0xc405 ^ machines as u64, machines, dense_len, density);
            let stats = MeasuredStats::from_tensors(&inputs, &[machines], &[DEFAULT_BLOCK]);
            let topo = Topology::flat(machines, link);
            let plan = plan_bucket("cell", dense_len as f64, machines, &topo, &cfg, stats);

            let net = Network::new(machines, link);
            let measured: Vec<(&str, f64)> = PLANNER_CANDIDATES
                .iter()
                .map(|&name| (name, measured_time(name, &inputs, &net)))
                .collect();
            let (best_name, best_time) = measured
                .iter()
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .copied()
                .unwrap();
            let (_, chosen_time) = measured
                .iter()
                .find(|(name, _)| *name == plan.chosen)
                .copied()
                .unwrap();
            assert!(
                chosen_time <= best_time * 1.35,
                "n={machines} d={density}: planner chose {} ({chosen_time:.2e}s), \
                 measured best is {best_name} ({best_time:.2e}s) — beyond tie margin.\n\
                 ranked: {:?}",
                plan.chosen,
                plan.costs
                    .iter()
                    .map(|c| (c.scheme, c.time))
                    .collect::<Vec<_>>()
            );
        }
    }
}

#[test]
fn non_power_of_two_machines_plan_without_panic() {
    // The old CostModel::sparcml asserted 2^k nodes; the planner must
    // rank every candidate at n = 6 (and the choice must execute).
    let machines = 6;
    let inputs = random_uniform_inputs(0x6666, machines, 1 << 13, 0.02);
    let planner = CostPlanner::new(machines, 0x5eed, 256, PlanConfig::default());
    let planned = planner.plan("n6", &inputs, &Topology::flat(machines, LinkKind::Tcp25));
    let plan = planned.plan.expect("auto always plans");
    assert_eq!(plan.costs.len(), PLANNER_CANDIDATES.len());
    assert!(plan.costs.iter().all(|c| c.time.is_finite()));
    let net = Network::new(machines, LinkKind::Tcp25);
    let r = planned
        .scheme
        .run_sim(&inputs, &net, &mut SyncScratch::new());
    schemes::verify_outputs(&r, &inputs);
}

#[test]
fn repeated_profiling_returns_identical_stats() {
    // MeasuredStats caching contract, both halves: (1) profiling the
    // same tensors twice yields value-identical stats; (2) the planner
    // serves the *same* cached stats object across iterations instead
    // of re-profiling.
    let inputs = random_uniform_inputs(0xcace, 4, 1 << 13, 0.03);
    let a = MeasuredStats::from_tensors(&inputs, &[4], &[DEFAULT_BLOCK]);
    let b = MeasuredStats::from_tensors(&inputs, &[4], &[DEFAULT_BLOCK]);
    assert_eq!(a, b, "profiling is deterministic");

    let planner = CostPlanner::new(4, 0x5eed, 256, PlanConfig::default());
    let tcp = Topology::flat(4, LinkKind::Tcp25);
    let first = planner.plan("bucket", &inputs, &tcp).plan.unwrap();
    let second = planner.plan("bucket", &inputs, &tcp).plan.unwrap();
    assert!(
        std::sync::Arc::ptr_eq(&first, &second),
        "cached plan (and its stats) must be the same object"
    );
    assert_eq!(first.stats, a, "cached stats equal a fresh profile");
    assert_eq!(planner.profile_count(), 1, "no re-profiling at steady state");
}

#[test]
fn hysteresis_zero_replans_on_any_drift() {
    let cfg = PlanConfig {
        replan_threshold: 0.0,
        ..PlanConfig::default()
    };
    let planner = CostPlanner::new(4, 0x5eed, 256, cfg);
    let tcp = Topology::flat(4, LinkKind::Tcp25);
    planner.plan("b", &random_uniform_inputs(1, 4, 4096, 0.020), &tcp);
    // ~10% denser: outside a zero threshold, inside the default 0.25
    planner.plan("b", &random_uniform_inputs(2, 4, 4096, 0.022), &tcp);
    assert_eq!(planner.profile_count(), 2, "zero hysteresis re-plans");
}
