//! Sim-vs-channel parity harness (ISSUE 3 acceptance): every scheme ×
//! machine-count × density cell must produce
//!
//! 1. identical per-stage byte matrices on `SimTransport` (virtual
//!    time, frames only counted) and `ChannelTransport` (frames really
//!    encoded, moved through channels, decoded),
//! 2. fabric byte counters that agree with the scheme's `CommReport`
//!    per endpoint, and
//! 3. outputs equal to the dense reference (lossless schemes) and
//!    bit-identical across backends (all schemes).
//!
//! A socket smoke cell additionally runs two schemes over the real
//! loopback socket mesh ([`SocketDriver`]).

use zen::cluster::{LinkKind, Network, Topology, LINK_CLASSES};
use zen::schemes::{self, SyncScheme, SyncScratch};
use zen::wire::{ChannelTransport, SocketDriver, TransportDriver};
use zen::workload::random_uniform_inputs as random_inputs;

/// The seven schemes of the paper's taxonomy, by CLI name, plus the
/// folded AGsparse-hier variant (its non-power-of-two schedule is
/// exactly what the {3, 5, 6, 12} grid exists to cover).
const SCHEMES: &[&str] = &[
    "dense",
    "agsparse",
    "agsparse-hier",
    "sparcml",
    "sparseps",
    "omnireduce",
    "strawman:64",
    "zen",
];

fn assert_parity_cell(name: &str, machines: usize, density: f64) {
    let dense_len = 6_000;
    let inputs = random_inputs(
        0x9a17 ^ machines as u64 ^ (density * 1000.0) as u64,
        machines,
        dense_len,
        density,
    );
    let nnz = inputs[0].nnz().max(8);
    let scheme = schemes::by_name(name, machines, 0xace5, nnz).unwrap();
    let net = Network::new(machines, LinkKind::Tcp25);
    let ctx = format!("{name} m={machines} d={density}");

    let sim = scheme.run_sim(&inputs, &net, &mut SyncScratch::new());
    let mut ch = ChannelTransport::new(net.clone());
    let chan = {
        let mut drv = TransportDriver::over(&mut ch);
        scheme
            .run(&inputs, &mut drv, &mut SyncScratch::new())
            .unwrap_or_else(|e| panic!("{ctx}: channel sync failed: {e}"))
    };

    // 1. per-stage byte parity
    assert_eq!(
        sim.report.stages.len(),
        chan.report.stages.len(),
        "{ctx}: stage count"
    );
    for (s, c) in sim.report.stages.iter().zip(chan.report.stages.iter()) {
        assert_eq!(s.name, c.name, "{ctx}: stage name");
        assert_eq!(s.sent, c.sent, "{ctx}: stage '{}' sent bytes", s.name);
        assert_eq!(s.recv, c.recv, "{ctx}: stage '{}' recv bytes", s.name);
        assert!((s.time - c.time).abs() < 1e-15, "{ctx}: stage time");
    }

    // 2. fabric counters == report accounting, per endpoint
    for e in 0..machines {
        let rep_sent: u64 = chan.report.stages.iter().map(|st| st.sent[e]).sum();
        let rep_recv: u64 = chan.report.stages.iter().map(|st| st.recv[e]).sum();
        assert_eq!(ch.fabric().sent_bytes(e), rep_sent, "{ctx}: counter sent[{e}]");
        assert_eq!(ch.fabric().recv_bytes(e), rep_recv, "{ctx}: counter recv[{e}]");
    }

    // 3. outputs: bit-identical across backends; reference-exact for
    // lossless schemes (the strawman is lossy by design).
    assert_eq!(sim.outputs.len(), chan.outputs.len(), "{ctx}");
    for (a, b) in sim.outputs.iter().zip(chan.outputs.iter()) {
        assert_eq!(a, b, "{ctx}: outputs diverge across backends");
    }
    if !name.starts_with("strawman") {
        schemes::verify_outputs(&chan, &inputs);
    }
}

fn parity_grid(machines: usize) {
    for name in SCHEMES {
        for density in [0.01, 0.1] {
            assert_parity_cell(name, machines, density);
        }
    }
}

#[test]
fn parity_all_schemes_2_machines() {
    parity_grid(2);
}

#[test]
fn parity_all_schemes_4_machines() {
    parity_grid(4);
}

#[test]
fn parity_all_schemes_8_machines() {
    parity_grid(8);
}

#[test]
fn parity_all_schemes_non_pow2_machines() {
    // Heterogeneous-cluster counts: the non-power-of-two fold paths of
    // SparCML and AGsparse-hier (plus everyone else's generic loops)
    // must hold stage-exact parity too. One density per cell keeps the
    // grid affordable; the pow-2 grids above cover the density sweep.
    for machines in [3usize, 5, 6, 12] {
        for name in SCHEMES {
            assert_parity_cell(name, machines, 0.02);
        }
    }
}

#[test]
fn topology_parity_per_link_class() {
    // Two-level placement: sim and channel must agree not just on the
    // total byte matrix but on the per-link-class split — bytes and
    // busiest endpoint per class, stage by stage.
    let topo = Topology::two_level(4, 2, LinkKind::NvLink, LinkKind::Tcp25);
    let net = Network::with_topology(topo);
    let machines = net.endpoints;
    let inputs = random_inputs(0x707, machines, 6_000, 0.03);
    for name in ["zen", "sparcml", "dense", "agsparse-hier"] {
        let scheme = schemes::by_name(name, machines, 0xace5, inputs[0].nnz()).unwrap();
        let sim = scheme.run_sim(&inputs, &net, &mut SyncScratch::new());
        let mut ch = ChannelTransport::new(net.clone());
        let chan = {
            let mut drv = TransportDriver::over(&mut ch);
            scheme
                .run(&inputs, &mut drv, &mut SyncScratch::new())
                .unwrap_or_else(|e| panic!("{name}: channel sync failed: {e}"))
        };
        assert_eq!(sim.report.stages.len(), chan.report.stages.len(), "{name}");
        let mut intra_seen = false;
        for (s, c) in sim.report.stages.iter().zip(chan.report.stages.iter()) {
            for class in LINK_CLASSES {
                let (a, b) = (&s.classes[class.idx()], &c.classes[class.idx()]);
                assert_eq!(a.bytes, b.bytes, "{name}: stage '{}' {} bytes", s.name, class.name());
                assert_eq!(
                    a.busiest,
                    b.busiest,
                    "{name}: stage '{}' {} busiest",
                    s.name,
                    class.name()
                );
                assert!((a.time - b.time).abs() < 1e-15, "{name}: class time");
            }
            intra_seen |= s.classes[0].bytes > 0;
            // stage charge is the max over the classes
            let expect = s.classes[0].time.max(s.classes[1].time);
            assert!((s.time - expect).abs() < 1e-15, "{name}: stage '{}'", s.name);
        }
        assert!(intra_seen, "{name}: co-located ranks must exchange intra-class bytes");
        assert_eq!(sim.report.bytes_by_class(), chan.report.bytes_by_class(), "{name}");
        for (a, b) in sim.outputs.iter().zip(chan.outputs.iter()) {
            assert_eq!(a, b, "{name}: outputs diverge across backends");
        }
        schemes::verify_outputs(&chan, &inputs);
    }
}

#[test]
fn socket_loopback_matches_sim_smoke() {
    // Real sockets: the readiness-polled loopback mesh, two
    // representative schemes. Per-peer queues mean payload size is no
    // longer capped by the kernel socket buffer.
    let machines = 3;
    let dense_len = 2_048;
    let inputs = random_inputs(0x7c9, machines, dense_len, 0.05);
    let net = Network::new(machines, LinkKind::Tcp25);
    for name in ["zen", "dense"] {
        let scheme = schemes::by_name(name, machines, 0xace5, inputs[0].nnz()).unwrap();
        let sim = scheme.run_sim(&inputs, &net, &mut SyncScratch::new());
        let mut sock = match SocketDriver::mesh(net.clone()) {
            Ok(t) => t,
            Err(e) => {
                // Sandboxes may forbid loopback sockets; the channel
                // parity above already covers the protocol path.
                eprintln!("skipping socket parity ({name}): {e}");
                return;
            }
        };
        let real = scheme
            .run(&inputs, &mut sock, &mut SyncScratch::new())
            .unwrap_or_else(|e| panic!("{name}: socket sync failed: {e}"));
        assert_eq!(sim.report.stages.len(), real.report.stages.len(), "{name}");
        for (s, c) in sim.report.stages.iter().zip(real.report.stages.iter()) {
            assert_eq!(s.sent, c.sent, "{name}: socket stage '{}' sent", s.name);
            assert_eq!(s.recv, c.recv, "{name}: socket stage '{}' recv", s.name);
        }
        for (a, b) in sim.outputs.iter().zip(real.outputs.iter()) {
            assert_eq!(a, b, "{name}: socket outputs diverge");
        }
        schemes::verify_outputs(&real, &inputs);
    }
}

#[test]
fn transport_reuse_across_sequential_syncs() {
    // One transport instance serves many syncs: `take_report` must fully
    // reset state so back-to-back runs are independent and identical.
    let machines = 4;
    let net = Network::new(machines, LinkKind::Tcp25);
    let inputs = random_inputs(0xbeefcafe, machines, 4_000, 0.02);
    let scheme = schemes::by_name("zen", machines, 1, inputs[0].nnz()).unwrap();
    let mut ch = ChannelTransport::new(net.clone());
    let mut drv = TransportDriver::over(&mut ch);
    let mut scratch = SyncScratch::new();
    let first = scheme
        .run(&inputs, &mut drv, &mut scratch)
        .expect("first sync");
    let second = scheme
        .run(&inputs, &mut drv, &mut scratch)
        .expect("second sync");
    assert_eq!(
        first.report.total_bytes(),
        second.report.total_bytes(),
        "reused transport must not leak state between syncs"
    );
    assert_eq!(first.outputs, second.outputs);
}
