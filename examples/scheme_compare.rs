//! Scheme design-space comparison (paper §2.3, Table 2 + Fig 7):
//! classify every scheme by the four dimensions and reproduce the
//! normalized communication-time sweep on the NMT workload.
//!
//!   cargo run --release --example scheme_compare

use zen::figures;

fn main() {
    println!("{}", figures::table2().to_markdown());
    println!("{}", figures::fig7().to_markdown());
    println!(
        "Reading the sweep: AGsparse degrades linearly and crosses Dense; \
         Sparse PS suffers the skewness ratio; OmniReduce's advantage fades \
         as aggregation densifies its blocks; Zen (Balanced Parallelism) \
         stays below Dense even at 128 machines — Theorem 1.2's regime."
    );
}
