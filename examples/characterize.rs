//! Characterize sparse gradient tensors (paper §2.2, Figs 1–2, Table 1):
//! overlap ratios, densification, skewness — on all four model profiles.
//!
//!   cargo run --release --example characterize

use zen::figures;

fn main() {
    for t in [
        figures::table1(),
        figures::fig1a(),
        figures::fig1b(),
        figures::fig2a(),
        figures::fig2b(),
    ] {
        println!("{}", t.to_markdown());
    }
}
