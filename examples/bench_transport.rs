//! Transport-overhead trajectory: every scheme over every data plane,
//! emitted as machine-readable `BENCH_PR6.json` so the cost of moving
//! real frames (channel) and real sockets (the readiness-polled
//! loopback mesh) versus the virtual-time simulator is re-measurable on
//! any machine.
//!
//!   cargo run --release --example bench_transport -- [--tiny] [--iters K] [--out PATH]
//!
//! - `--tiny`: CI smoke configuration (small tensors, few iterations).
//! - `--iters K`: timed iterations per cell (median reported).
//! - `--out PATH`: output JSON path (default `BENCH_PR6.json`).
//!
//! Unlike the retired single-threaded TCP loopback, the socket mesh
//! queues writes per peer and never blocks, so payload size is bounded
//! by memory, not the kernel socket buffer.

use zen::cluster::{LinkKind, Network};
use zen::schemes::{self, SyncScheme, SyncScratch};
use zen::util::{Stopwatch, Summary};
use zen::wire::{make_driver, TransportKind};
use zen::workload::random_uniform_inputs as random_inputs;

struct Config {
    tiny: bool,
    iters: usize,
    warmup: usize,
    out: String,
}

fn parse_args() -> Config {
    let mut cfg = Config {
        tiny: false,
        iters: 7,
        warmup: 2,
        out: "BENCH_PR6.json".to_string(),
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--tiny" => {
                cfg.tiny = true;
                cfg.iters = 3;
                cfg.warmup = 1;
            }
            "--iters" => {
                cfg.iters = args
                    .next()
                    .and_then(|v| v.parse().ok())
                    .expect("--iters needs a positive integer");
            }
            "--out" => {
                cfg.out = args.next().expect("--out needs a path");
            }
            other => panic!("unknown argument {other}"),
        }
    }
    cfg
}

fn median_ns<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        s.add(sw.elapsed() * 1e9);
    }
    s.median()
}

fn json_f(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.1}")
    } else {
        "null".to_string()
    }
}

fn main() {
    let cfg = parse_args();
    let machines = 4;
    let (dense_len, density) = if cfg.tiny {
        (1 << 12, 0.02)
    } else {
        (1 << 14, 0.02)
    };
    let inputs = random_inputs(0x9137, machines, dense_len, density);
    let net = Network::new(machines, LinkKind::Tcp25);
    let nnz = inputs[0].nnz();
    let scheme_names = [
        "zen",
        "zen-coo",
        "sparseps",
        "omnireduce",
        "sparcml",
        "agsparse",
        "strawman:8",
        "dense",
    ];
    let backends = [
        TransportKind::Sim,
        TransportKind::Channel,
        TransportKind::Socket,
    ];

    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"pr\": 6,\n");
    json.push_str(&format!(
        "  \"config\": {{\"tiny\": {}, \"iters\": {}, \"warmup\": {}, \
         \"machines\": {machines}, \"dense_len\": {dense_len}, \"density\": {density}}},\n",
        cfg.tiny, cfg.iters, cfg.warmup
    ));
    json.push_str("  \"grid\": [\n");

    let mut rows: Vec<String> = Vec::new();
    for name in scheme_names {
        let scheme = schemes::by_name(name, machines, 0x5eed, nnz).unwrap();
        let mut sim_ns = f64::NAN;
        for kind in backends {
            // One driver per cell, reused across iterations (the socket
            // mesh persists; take_report resets per sync).
            let mut drv = match make_driver(kind, &net) {
                Ok(d) => d,
                Err(e) => {
                    eprintln!("{name}/{}: backend unavailable ({e})", kind.name());
                    rows.push(format!(
                        "    {{\"scheme\": \"{}\", \"transport\": \"{}\", \
                         \"ns_per_iter_median\": null, \"bytes_per_iter\": null, \
                         \"overhead_vs_sim\": null}}",
                        scheme.name(),
                        kind.name()
                    ));
                    continue;
                }
            };
            let mut scratch = SyncScratch::new();
            let mut bytes = 0u64;
            let ns = median_ns(cfg.warmup, cfg.iters, || {
                let r = scheme
                    .run(&inputs, drv.as_mut(), &mut scratch)
                    .expect("bench sync");
                bytes = r.report.total_bytes();
                std::hint::black_box(r.outputs.len());
            });
            if kind == TransportKind::Sim {
                sim_ns = ns;
            }
            let overhead = ns / sim_ns;
            println!(
                "{:<14} {:<8} {:>10.1} us/iter  {:>12} B/iter  ({:.2}x vs sim)",
                scheme.name(),
                kind.name(),
                ns / 1e3,
                bytes,
                overhead
            );
            rows.push(format!(
                "    {{\"scheme\": \"{}\", \"transport\": \"{}\", \
                 \"ns_per_iter_median\": {}, \"bytes_per_iter\": {bytes}, \
                 \"overhead_vs_sim\": {}}}",
                scheme.name(),
                kind.name(),
                json_f(ns),
                if overhead.is_finite() {
                    format!("{overhead:.3}")
                } else {
                    "null".to_string()
                }
            ));
        }
    }
    json.push_str(&rows.join(",\n"));
    json.push_str("\n  ]\n}\n");
    std::fs::write(&cfg.out, &json).expect("write bench json");
    println!("wrote {}", cfg.out);
}
