//! Planner trajectory: the auto-planner versus every fixed scheme on
//! each Table-1 workload, emitted as machine-readable `BENCH_PR4.json`
//! so the planner's headline claim — per-bucket scheme choice beats
//! the best single fixed scheme — is re-measurable on any machine.
//!
//!   cargo run --release --example bench_planner -- [--tiny] [--iters K] [--out PATH]
//!
//! Each workload runs the pipelined engine path (dense head buckets +
//! embedding shard buckets) once per scheme in
//! `schemes::PLANNER_CANDIDATES`, then with `--scheme auto`; the metric
//! is the mean total bucket communication time per iteration
//! (`SimResult::emb_sync_mean`, full-size virtual seconds). The JSON
//! records auto vs best-fixed vs worst-fixed plus auto's per-bucket
//! plan (chosen scheme, predicted and measured time), and CI uploads
//! it to the `bench-trajectory` artifact next to BENCH_PR2/PR3.

use zen::coordinator::{PipelineConfig, SimConfig, SimDriver};
use zen::schemes::PLANNER_CANDIDATES;
use zen::workload::profiles;

struct Config {
    tiny: bool,
    iters: usize,
    out: String,
}

fn parse_args() -> Config {
    let mut cfg = Config {
        tiny: false,
        iters: 2,
        out: "BENCH_PR4.json".to_string(),
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--tiny" => {
                cfg.tiny = true;
                cfg.iters = 1;
            }
            "--iters" => {
                cfg.iters = args
                    .next()
                    .and_then(|v| v.parse().ok())
                    .expect("--iters needs a positive integer");
            }
            "--out" => {
                cfg.out = args.next().expect("--out needs a path");
            }
            other => panic!("unknown argument {other}"),
        }
    }
    cfg
}

fn sim(
    model: &str,
    scheme: &str,
    machines: usize,
    scale: usize,
    iters: usize,
) -> zen::coordinator::SimResult {
    let mut cfg = SimConfig::new(profiles::by_name(model).unwrap(), machines, scheme);
    cfg.scale = scale;
    cfg.iterations = iters;
    cfg.gpus_per_machine = 2;
    cfg.pipeline = Some(PipelineConfig {
        bucket_bytes: 64 * 1024,
        dense_layers: 3,
        emb_shards: 4,
        ..PipelineConfig::default()
    });
    SimDriver::new(cfg).expect("bench config").run()
}

fn main() {
    let cfg = parse_args();
    let (models, machines, scale): (&[&str], usize, usize) = if cfg.tiny {
        (&["DeepFM", "LSTM"], 8, 1024)
    } else {
        (&["LSTM", "DeepFM", "NMT", "BERT"], 16, 512)
    };

    let mut rows: Vec<String> = Vec::new();
    let mut auto_wins = 0usize;
    for model in models {
        let mut fixed: Vec<(String, f64)> = Vec::new();
        for scheme in PLANNER_CANDIDATES {
            let r = sim(model, scheme, machines, scale, cfg.iters);
            fixed.push((r.scheme.clone(), r.emb_sync_mean));
        }
        let auto = sim(model, "auto", machines, scale, cfg.iters);
        let (best_name, best) = fixed
            .iter()
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .cloned()
            .unwrap();
        let (worst_name, worst) = fixed
            .iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .cloned()
            .unwrap();
        let auto_le_best = auto.emb_sync_mean <= best;
        auto_wins += auto_le_best as usize;
        println!(
            "{model:<8} auto {:>9.3}ms | best fixed {best_name:<10} {:>9.3}ms | \
             worst fixed {worst_name:<10} {:>9.3}ms | auto<=best: {auto_le_best}",
            auto.emb_sync_mean * 1e3,
            best * 1e3,
            worst * 1e3
        );
        for p in &auto.plan {
            println!(
                "    {:<14} {:<12} predicted {:>9.3}ms  measured {:>9.3}ms",
                p.label,
                p.scheme,
                p.predicted.unwrap_or(f64::NAN) * 1e3,
                p.measured * 1e3
            );
        }
        let plan_json: Vec<String> = auto
            .plan
            .iter()
            .map(|p| {
                // `null`, never `NaN` — NaN is not valid JSON.
                let predicted = p
                    .predicted
                    .map(|v| format!("{v:.6e}"))
                    .unwrap_or_else(|| "null".to_string());
                format!(
                    "{{\"bucket\": \"{}\", \"scheme\": \"{}\", \"predicted_s\": {predicted}, \
                     \"measured_s\": {:.6e}}}",
                    p.label, p.scheme, p.measured
                )
            })
            .collect();
        let fixed_json: Vec<String> = fixed
            .iter()
            .map(|(name, t)| format!("{{\"scheme\": \"{name}\", \"sync_s\": {t:.6e}}}"))
            .collect();
        rows.push(format!(
            "    {{\"model\": \"{model}\", \"machines\": {machines}, \
             \"auto_sync_s\": {:.6e}, \"best_fixed\": \"{best_name}\", \
             \"best_fixed_sync_s\": {best:.6e}, \"worst_fixed\": \"{worst_name}\", \
             \"worst_fixed_sync_s\": {worst:.6e}, \"auto_le_best_fixed\": {auto_le_best},\n     \
             \"plan\": [{}],\n     \"fixed\": [{}]}}",
            auto.emb_sync_mean,
            plan_json.join(", "),
            fixed_json.join(", ")
        ));
    }

    let json = format!(
        "{{\n  \"pr\": 4,\n  \"config\": {{\"tiny\": {}, \"iters\": {}, \"machines\": {machines}, \
         \"scale\": {scale}}},\n  \"auto_wins\": {auto_wins},\n  \"workloads\": [\n{}\n  ]\n}}\n",
        cfg.tiny,
        cfg.iters,
        rows.join(",\n")
    );
    std::fs::write(&cfg.out, &json).expect("write bench json");
    println!("wrote {} (auto <= best fixed on {auto_wins}/{} workloads)", cfg.out, models.len());
    assert!(
        auto_wins >= 1,
        "acceptance: the planner must match or beat the best fixed scheme on at least one workload"
    );
}
