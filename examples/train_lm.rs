//! End-to-end driver: really train a ~100M-parameter embedding LM with
//! sparse gradient synchronization through the full three-layer stack —
//! JAX/Pallas train step (AOT → HLO), rust PJRT execution, Zen
//! synchronization — and log the loss curve + per-scheme timing.
//!
//!   cargo run --release --example train_lm                      # 100M model
//!   cargo run --release --example train_lm -- --shape tiny      # smoke
//!   cargo run --release --example train_lm -- --steps 300 --workers 8
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use zen::cluster::LinkKind;
use zen::config::Args;
use zen::coordinator::lm::{LmConfig, LmTrainer};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let shape = args.get_or("shape", "paper_100m");
    let mut cfg = match shape {
        "tiny" => LmConfig::tiny(),
        _ => LmConfig::paper_100m(),
    };
    cfg.seed = args.get_u64("seed", 0xe2e);
    let workers = args.get_usize("workers", 8);
    let steps = args.get_usize("steps", if shape == "tiny" { 100 } else { 300 });
    let log_every = args.get_usize("log-every", (steps / 12).max(1));
    let scheme = args.get_or("scheme", "zen");
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");

    println!(
        "=== end-to-end: {}×{} embedding + {}-hidden MLP = {:.1}M params, \
         {workers} data-parallel workers, scheme={scheme}, 25Gbps virtual net ===",
        cfg.vocab,
        cfg.dim,
        cfg.hidden,
        (cfg.emb_params() + cfg.mlp_params()) as f64 / 1e6
    );
    let sw = zen::util::Stopwatch::start();
    let mut trainer = LmTrainer::new(cfg, workers, scheme, LinkKind::Tcp25, &artifacts)?;
    let log = trainer.run(steps, log_every, true)?;
    let wall = sw.elapsed();

    println!("\n--- summary ---");
    println!("steps: {steps}, wall time: {wall:.1}s");
    println!(
        "loss: {:.4} -> {:.4}",
        log.losses.first().unwrap(),
        log.losses.last().unwrap()
    );
    if let (Some(first), Some(last)) = (log.accuracies.first(), log.accuracies.last()) {
        println!("eval accuracy: {:.3} -> {:.3}", first.1, last.1);
    }
    println!(
        "virtual comm: embedding {:.1}ms + mlp {:.1}ms; compute wall {:.1}s",
        log.emb_comm_total * 1e3,
        log.mlp_comm_total * 1e3,
        log.compute_wall_total
    );

    // Per-scheme comm comparison on the final gradient scale.
    println!("\nper-step embedding sync time by scheme (same workload):");
    for s in ["allreduce", "sparcml", "omnireduce", "zen"] {
        let mut cfg2 = match shape {
            "tiny" => LmConfig::tiny(),
            _ => LmConfig::paper_100m(),
        };
        cfg2.seed = 0xe2e;
        let mut t2 = LmTrainer::new(cfg2, workers, s, LinkKind::Tcp25, &artifacts)?;
        let stats = t2.step()?;
        println!("  {:<12} {:>8.2} ms", s, stats.emb_comm_time * 1e3);
    }
    Ok(())
}
