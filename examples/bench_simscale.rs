//! Simulation-scale trajectory (PR 7): how far one thread goes.
//!
//! Two measurements, emitted as machine-readable `BENCH_PR7.json`:
//!
//! 1. **Head-to-head** at n ∈ {8, 64}: the same scheme over the
//!    discrete-event driver (one thread, one heap) versus the
//!    thread-per-rank driver (n OS threads + a coordinator). The ratio
//!    is the cost of simulating concurrency with real concurrency —
//!    the event driver's reason to exist.
//! 2. **Scale sweep**: every scheme at large n (1024 ranks; 256 under
//!    `--tiny`) on a two-level topology, one thread, totals-only
//!    accounting — reporting wall clock, events/sec, and the event
//!    pool's high-water mark (peak concurrent in-flight frames, the
//!    run's peak-memory proxy).
//!
//!   cargo run --release --example bench_simscale -- [--tiny] [--iters K] [--out PATH]
//!
//! - `--tiny`: CI smoke configuration (small tensors, 256-rank sweep).
//! - `--iters K`: timed iterations per head-to-head cell (median).
//! - `--out PATH`: output JSON path (default `BENCH_PR7.json`).

use zen::cluster::{LinkKind, Network, Topology};
use zen::schemes::{self, SyncScheme, SyncScratch};
use zen::util::{Stopwatch, Summary};
use zen::wire::{EventDriver, ThreadedDriver};
use zen::workload::random_uniform_inputs as random_inputs;

struct Config {
    tiny: bool,
    iters: usize,
    warmup: usize,
    out: String,
}

fn parse_args() -> Config {
    let mut cfg = Config {
        tiny: false,
        iters: 5,
        warmup: 1,
        out: "BENCH_PR7.json".to_string(),
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--tiny" => {
                cfg.tiny = true;
                cfg.iters = 3;
            }
            "--iters" => {
                cfg.iters = args
                    .next()
                    .and_then(|v| v.parse().ok())
                    .expect("--iters needs a positive integer");
            }
            "--out" => {
                cfg.out = args.next().expect("--out needs a path");
            }
            other => panic!("unknown argument {other}"),
        }
    }
    cfg
}

fn median_ns<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        s.add(sw.elapsed() * 1e9);
    }
    s.median()
}

fn json_f(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.1}")
    } else {
        "null".to_string()
    }
}

fn main() {
    let cfg = parse_args();
    let dense_len = if cfg.tiny { 1 << 12 } else { 1 << 14 };
    let density = 0.02;

    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"pr\": 7,\n");
    json.push_str(&format!(
        "  \"config\": {{\"tiny\": {}, \"iters\": {}, \"warmup\": {}, \
         \"dense_len\": {dense_len}, \"density\": {density}}},\n",
        cfg.tiny, cfg.iters, cfg.warmup
    ));

    // -- 1. event driver vs thread-per-rank, same scheme same inputs --
    json.push_str("  \"head_to_head\": [\n");
    let mut rows: Vec<String> = Vec::new();
    for machines in [8usize, 64] {
        let inputs = random_inputs(0x51ca ^ machines as u64, machines, dense_len, density);
        let nnz = inputs[0].nnz().max(8);
        let scheme = schemes::by_name("zen", machines, 0x5eed, nnz).unwrap();
        let net = Network::new(machines, LinkKind::Tcp25);

        let mut ev = EventDriver::new(net.clone());
        let mut scratch = SyncScratch::new();
        let event_ns = median_ns(cfg.warmup, cfg.iters, || {
            let r = scheme
                .run(&inputs, &mut ev, &mut scratch)
                .expect("event sync");
            std::hint::black_box(r.outputs.len());
        });

        let mut th = ThreadedDriver::new(net);
        let threaded_ns = median_ns(cfg.warmup, cfg.iters, || {
            let r = scheme
                .run(&inputs, &mut th, &mut scratch)
                .expect("threaded sync");
            std::hint::black_box(r.outputs.len());
        });

        let speedup = threaded_ns / event_ns;
        println!(
            "n={machines:<4} event {:>10.1} us/iter   thread-per-rank {:>10.1} us/iter   ({speedup:.1}x)",
            event_ns / 1e3,
            threaded_ns / 1e3
        );
        rows.push(format!(
            "    {{\"machines\": {machines}, \"event_ns_median\": {}, \
             \"threaded_ns_median\": {}, \"event_speedup\": {}}}",
            json_f(event_ns),
            json_f(threaded_ns),
            if speedup.is_finite() {
                format!("{speedup:.2}")
            } else {
                "null".to_string()
            }
        ));
    }
    json.push_str(&rows.join(",\n"));
    json.push_str("\n  ],\n");

    // -- 2. all schemes at large n, one thread, totals-only -----------
    let ranks = if cfg.tiny { 256usize } else { 1024 };
    let (nodes, per_node) = (ranks / 32, 32usize);
    let sweep_dense = 1 << 12;
    let sweep_inputs = random_inputs(0x1024, ranks, sweep_dense, 0.005);
    let sweep_nnz = sweep_inputs[0].nnz().max(8);
    let topo = Topology::two_level(
        nodes,
        per_node,
        LinkKind::Custom(250_000_000_000, 2_000),
        LinkKind::Custom(25_000_000_000, 50_000),
    );
    let net = Network::with_topology(topo);
    let sweep_schemes = [
        "zen",
        "zen-coo",
        "sparseps",
        "omnireduce",
        "sparcml",
        "agsparse",
        "agsparse-ring",
        "agsparse-hier",
        "dense",
    ];

    json.push_str("  \"sweep\": [\n");
    let mut rows: Vec<String> = Vec::new();
    for name in sweep_schemes {
        let scheme = schemes::by_name(name, ranks, 0x5eed, sweep_nnz).unwrap();
        let mut drv = EventDriver::new(net.clone()).totals_only();
        let mut scratch = SyncScratch::new();
        let sw = Stopwatch::start();
        let r = scheme
            .run(&sweep_inputs, &mut drv, &mut scratch)
            .expect("sweep sync");
        let wall = sw.elapsed();
        std::hint::black_box(r.outputs.len());
        let events = drv.events_processed();
        let eps = events as f64 / wall.max(1e-12);
        println!(
            "{name:<14} n={ranks}  {:>8.1} ms wall  {:>12} events  {:>12.0} ev/s  pool {:>6}  vt {:.3e}s",
            wall * 1e3,
            events,
            eps,
            drv.pool_high_water(),
            drv.virtual_time()
        );
        rows.push(format!(
            "    {{\"scheme\": \"{}\", \"machines\": {ranks}, \"wall_ms\": {}, \
             \"events\": {events}, \"events_per_sec\": {}, \
             \"pool_high_water\": {}, \"virtual_time_s\": {}}}",
            scheme.name(),
            json_f(wall * 1e3),
            json_f(eps),
            drv.pool_high_water(),
            json_f(drv.virtual_time())
        ));
    }
    json.push_str(&rows.join(",\n"));
    json.push_str("\n  ]\n}\n");
    std::fs::write(&cfg.out, &json).expect("write bench json");
    println!("wrote {}", cfg.out);
}
