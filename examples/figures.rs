//! Regenerate every paper table and figure.
//!
//! Usage:
//!   cargo run --release --example figures -- all
//!   cargo run --release --example figures -- fig7 fig13 table1 ...
//!   cargo run --release --example figures -- fig14      # needs artifacts
//!
//! Each exhibit prints as markdown and is saved to reports/<slug>.csv.

use zen::cluster::LinkKind;
use zen::figures;
use zen::util::table::Table;

fn reports_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("reports")
}

fn emit(t: Table) {
    println!("{}", t.to_markdown());
    match t.save_csv(&reports_dir()) {
        Ok(p) => println!("(saved {})\n", p.display()),
        Err(e) => eprintln!("(csv save failed: {e})"),
    }
}

/// Fig 14 — accuracy preservation: AllReduce vs Zen vs lossy strawman.
/// Needs `make artifacts` (runs the real trainer on the tiny shape).
fn fig14() -> anyhow::Result<Table> {
    use zen::coordinator::lm::{LmConfig, LmTrainer};
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut t = Table::new(
        "Fig 14 — accuracy with lossless vs lossy synchronization",
        &["scheme", "step", "loss", "eval accuracy"],
    );
    // strawman:1.2 ≈ heavy loss, strawman:16 ≈ mild loss (slot multiples
    // of expected nnz; see DESIGN.md for the mapping to the paper's
    // 2|G| / 8|G| memory sizes).
    for scheme in ["allreduce", "zen", "strawman:1.2", "strawman:16"] {
        let mut cfg = LmConfig::tiny();
        cfg.seed = 0x14; // identical init across schemes
        let mut trainer = LmTrainer::new(cfg, 4, scheme, LinkKind::Tcp25, &artifacts)?;
        let log = trainer.run(120, 15, false)?;
        for (step, acc) in &log.accuracies {
            t.row(vec![
                scheme.into(),
                step.to_string(),
                format!("{:.4}", log.losses[*step]),
                format!("{acc:.3}"),
            ]);
        }
    }
    Ok(t)
}

/// Fig C — convergence vs synchronized volume: the lossy tier's
/// tradeoff curve. Same model, same data, same scheme (zen); only the
/// `--compress` spec varies. Error feedback keeps the destination
/// loss close to lossless while Top-k cuts the wire volume by the
/// selection ratio. Needs `make artifacts` like fig14.
fn figc() -> anyhow::Result<Table> {
    use zen::compress::CompressSpec;
    use zen::coordinator::lm::{LmConfig, LmTrainer};
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut t = Table::new(
        "Fig C — convergence vs synchronized volume (error-feedback compression)",
        &["compress", "steps", "final loss", "final accuracy", "wire MB", "lossy steps"],
    );
    let variants = [
        CompressSpec::None,
        CompressSpec::TopK(0.05),
        CompressSpec::TopK(0.01),
        CompressSpec::Threshold(1e-3),
    ];
    let steps = 120;
    for spec in variants {
        let mut cfg = LmConfig::tiny();
        cfg.seed = 0xf19c; // identical init across compressors
        cfg.compress = spec.clone();
        let mut trainer = LmTrainer::builder(cfg)
            .scheme("zen")
            .workers(4, LinkKind::Tcp25)
            .artifacts_dir(&artifacts)
            .build()?;
        let log = trainer.run(steps, 30, false)?;
        let acc = log.accuracies.last().map(|(_, a)| *a).unwrap_or(0.0);
        t.row(vec![
            spec.label(),
            steps.to_string(),
            format!("{:.4}", log.losses.last().copied().unwrap_or(f32::NAN)),
            format!("{acc:.3}"),
            format!("{:.2}", log.comm_bytes_total as f64 / 1e6),
            log.lossy_steps.to_string(),
        ]);
    }
    Ok(t)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let want = |name: &str| args.iter().any(|a| a == name || a == "all");
    if args.is_empty() {
        eprintln!(
            "usage: figures -- all | table1 table2 fig1 fig2 fig7 fig7m fig7e fig8 \
             fig11 fig12 fig13 fig14 fig15 fig16 fig17 fig18 figp figt figc"
        );
        return Ok(());
    }

    if want("table1") {
        emit(figures::table1());
    }
    if want("table2") {
        emit(figures::table2());
    }
    if want("fig1") {
        emit(figures::fig1a());
        emit(figures::fig1b());
    }
    if want("fig2") {
        emit(figures::fig2a());
        emit(figures::fig2b());
    }
    if want("fig7") {
        emit(figures::fig7());
    }
    if want("fig7m") {
        // Fig 7 re-derived from measured stats: cost-model predictions
        // next to transport-measured times, both normalized to Dense.
        emit(figures::fig7_measured());
    }
    if want("fig7e") {
        // Fig 7 at event-driver scale: the crossover swept to 512 ranks
        // on one thread (`--transport event` territory).
        emit(figures::fig7_event_scale());
    }
    if want("figp") {
        // Planner crossover map — the decision surface behind
        // `zen sim --scheme auto`.
        emit(figures::planner_crossover());
    }
    if want("figt") {
        // Topology crossover — where two-level pricing flips the
        // planner onto a hierarchical scheme (`--topology 4x2`).
        emit(figures::topology_crossover());
    }
    if want("fig8") {
        emit(figures::fig8());
    }
    if want("fig11") {
        emit(figures::fig11_12(
            LinkKind::Tcp25,
            "Fig 11 — training throughput, 25Gbps TCP",
        ));
    }
    if want("fig12") {
        emit(figures::fig11_12(
            LinkKind::Rdma100,
            "Fig 12 — training throughput, 100Gbps RDMA",
        ));
    }
    if want("fig13") {
        emit(figures::fig13());
    }
    if want("fig14") {
        emit(fig14()?);
    }
    if want("figc") {
        emit(figc()?);
    }
    if want("fig15") {
        emit(figures::fig15());
    }
    if want("fig16") {
        emit(figures::fig16());
    }
    if want("fig17") {
        emit(figures::fig17());
    }
    if want("fig18") {
        emit(figures::fig18());
    }
    Ok(())
}
