//! Perf probe: break one Zen synchronization of a 100M-model-shaped
//! gradient into phases and time each — drives the §Perf iteration log.
//! A second section probes the pipelined multi-tensor engine: bucket
//! count, wall time of the concurrent bucket syncs, and the virtual
//! serialized vs overlapped iteration times.
//!
//!   cargo run --release --example perf_probe

use zen::cluster::{LinkKind, Network};
use zen::coordinator::compute_time_per_iter;
use zen::engine::{EngineConfig, SyncEngine};
use zen::hashing::{HashBitmapCodec, HierarchicalHasher};
use zen::planner::FixedPlanner;
use zen::schemes::{self, SyncScheme};
use zen::tensor::CooTensor;
use zen::util::{Pcg64, Stopwatch};
use zen::workload::{profiles, GradientGen};

fn main() {
    // Shape of one worker's embedding gradient in the paper_100m run:
    // ~2.4k distinct rows × 512 dim ≈ 1.2M nnz over 100.7M params.
    let dense_len = 100_663_296usize;
    let dim = 512usize;
    let rows = 2_400usize;
    let workers = 8usize;
    let n = workers;

    let mut rng = Pcg64::seeded(1);
    let make_grad = |rng: &mut Pcg64| -> CooTensor {
        let mut row_ids = rng.sample_distinct(dense_len / dim, rows);
        row_ids.sort_unstable();
        let mut idx = Vec::with_capacity(rows * dim);
        let mut val = Vec::with_capacity(rows * dim);
        for r in row_ids {
            for c in 0..dim {
                idx.push((r * dim + c) as u32);
                val.push(0.5);
            }
        }
        CooTensor::from_sorted(dense_len, idx, val)
    };
    let sw = Stopwatch::start();
    let inputs: Vec<CooTensor> = (0..workers).map(|_| make_grad(&mut rng)).collect();
    println!("gen inputs        {:>8.1} ms  (nnz/worker {})", sw.elapsed() * 1e3, inputs[0].nnz());

    let hasher = HierarchicalHasher::with_defaults(7, n, inputs[0].nnz());
    let sw = Stopwatch::start();
    let parts: Vec<_> = inputs.iter().map(|t| hasher.partition(t)).collect();
    let hash_ms = sw.elapsed() * 1e3;
    println!(
        "alg1 hash x{workers}       {:>8.1} ms  ({:.1} M idx/s)",
        hash_ms,
        (workers * inputs[0].nnz()) as f64 / hash_ms * 1e-3
    );

    // server-side aggregation
    let sw = Stopwatch::start();
    let mut shards: Vec<Vec<CooTensor>> = vec![Vec::new(); n];
    for out in parts {
        for (p, part) in out.parts.into_iter().enumerate() {
            shards[p].push(part);
        }
    }
    let aggregated: Vec<CooTensor> = shards.iter().map(|s| CooTensor::merge_all(s)).collect();
    println!("server merge      {:>8.1} ms", sw.elapsed() * 1e3);

    // domains (one-time, amortized across the run)
    let sw = Stopwatch::start();
    let domains = hasher.partition_domains(dense_len);
    println!("domains (1-time)  {:>8.1} ms", sw.elapsed() * 1e3);

    // hash-bitmap pull encode
    let sw = Stopwatch::start();
    let payloads: Vec<_> = aggregated
        .iter()
        .enumerate()
        .map(|(p, t)| HashBitmapCodec::new(&domains[p]).encode(t))
        .collect();
    println!("hb encode x{n}      {:>8.1} ms", sw.elapsed() * 1e3);

    let sw = Stopwatch::start();
    let decoded: Vec<CooTensor> = payloads
        .iter()
        .enumerate()
        .map(|(p, pl)| HashBitmapCodec::new(&domains[p]).decode(pl, dense_len))
        .collect();
    println!("hb decode x{n}      {:>8.1} ms", sw.elapsed() * 1e3);

    let sw = Stopwatch::start();
    let full = CooTensor::merge_all(&decoded);
    println!("worker merge      {:>8.1} ms  (agg nnz {})", sw.elapsed() * 1e3, full.nnz());

    // --- multi-tensor engine probe: LSTM layers, 8 machines ---
    println!("\n== engine probe: LSTM (scaled 64), {n} machines, 256KB buckets ==");
    let profile = profiles::by_name("LSTM").unwrap().scaled(64);
    let gen = GradientGen::new(profile, 2);
    let specs = gen.layer_specs(4, 8);
    let sw = Stopwatch::start();
    let layers = gen.layer_iteration_all(&specs, 0, n);
    println!("gen {} layers x{n}  {:>8.1} ms", specs.len(), sw.elapsed() * 1e3);
    let net = Network::new(n, LinkKind::Tcp25);
    let engine = SyncEngine::new(EngineConfig::new(
        256 * 1024,
        compute_time_per_iter("LSTM"),
    ));
    for scheme_name in ["zen", "allreduce"] {
        let planner =
            FixedPlanner::new(schemes::by_name(scheme_name, n, 7, gen.expected_nnz()).unwrap());
        let run = engine.run(&specs, &layers, &planner, &net, |r| r.comm_time());
        println!(
            "{:<10} buckets {:>2}  sync wall {:>7.1} ms  virt serialized {:>7.2} ms  \
             overlapped {:>7.2} ms  ({:.2}x)",
            planner.scheme().name(),
            run.buckets.len(),
            run.wall_time * 1e3,
            run.serialized_time * 1e3,
            run.overlapped_time * 1e3,
            run.speedup()
        );
    }
}
