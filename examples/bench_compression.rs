//! PR 9 compression record: wire bytes and predicted sync time per
//! scheme, lossless vs error-feedback Top-k vs magnitude threshold, on
//! the Fig-7 workload (NMT profile, Table 1 density), emitted as
//! machine-readable `BENCH_PR9.json`.
//!
//!   cargo run --release --example bench_compression -- [--tiny] [--out PATH]
//!
//! - `--tiny`: CI smoke configuration (smaller scale, fewer iterations).
//! - `--out PATH`: output JSON path (default `BENCH_PR9.json`).
//!
//! Each (scheme, compressor) cell runs T iterations with ONE persistent
//! compressor, so the residual store reaches steady state and the
//! recorded reduction includes the re-offered error-feedback mass — the
//! honest number, not the first-iteration flash. The headline ratio
//! (Top-k keeping 1% of the gradient's entries must cut zen's wire
//! bytes by at least 5×) is printed and recorded, but this binary is a
//! measurement tool, not a gate: the hard assertion lives in
//! `tests/compress_integration.rs`.

use zen::cluster::{LinkKind, Network};
use zen::compress::{compress_all, CompressSpec};
use zen::schemes::{self, SyncScheme, SyncScratch};
use zen::tensor::CooTensor;
use zen::util::Stopwatch;
use zen::workload::{profiles, GradientGen};

struct Config {
    tiny: bool,
    out: String,
}

fn parse_args() -> Config {
    let mut cfg = Config {
        tiny: false,
        out: "BENCH_PR9.json".to_string(),
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--tiny" => cfg.tiny = true,
            "--out" => cfg.out = args.next().expect("--out needs a path"),
            other => panic!("unknown argument {other}"),
        }
    }
    cfg
}

struct Row {
    scheme: String,
    compress: String,
    bytes_per_iter: f64,
    entries_per_iter: f64,
    sim_time_s: f64,
    wall_ns_per_iter: f64,
}

fn main() {
    let cfg = parse_args();
    let (scale, machines, iters) = if cfg.tiny { (4096, 4, 4) } else { (256, 8, 8) };
    let profile = profiles::by_name("NMT").unwrap().scaled(scale);
    let gen = GradientGen::new(profile, 0x9_f16);
    let first: Vec<CooTensor> = (0..machines).map(|w| gen.iteration(1, w)).collect();
    let dense_len = first[0].dense_len;
    let nnz = first[0].nnz();

    // Top-k keeps 1% of the gradient's entries (an absolute count, so
    // the target is scheme-independent); the threshold is set at the
    // median magnitude of a real gradient, dropping roughly half.
    let k = ((nnz as f64 * 0.01).round() as usize).max(1);
    let mut mags: Vec<f32> = first[0].values.iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| a.total_cmp(b));
    let median = mags[mags.len() / 2].max(f32::MIN_POSITIVE);
    let variants: Vec<CompressSpec> = vec![
        CompressSpec::None,
        CompressSpec::TopK(k as f64),
        CompressSpec::Threshold(median),
    ];
    let scheme_names = ["zen", "zen-coo", "oktopk", "sparseps", "omnireduce", "dense"];

    println!(
        "fig7 workload: NMT/{scale}, m={machines}, dense_len={dense_len}, \
         nnz/worker={nnz}, topk k={k}, threshold={median}"
    );

    let net = Network::new(machines, LinkKind::Tcp25);
    let mut rows: Vec<Row> = Vec::new();
    for spec in &variants {
        for name in scheme_names {
            // One compressor per cell: residuals persist across the T
            // iterations, so later iterations ship re-offered mass too.
            let mut comp = spec.build();
            let mut scratch = SyncScratch::new();
            let mut scheme: Option<Box<dyn SyncScheme>> = None;
            let mut bytes = 0u64;
            let mut entries = 0u64;
            let mut sim_time = 0.0f64;
            let sw = Stopwatch::start();
            for t in 0..iters {
                let raw: Vec<CooTensor> =
                    (0..machines).map(|w| gen.iteration(t as u64 + 1, w)).collect();
                let inputs = match comp.as_mut() {
                    Some(c) => compress_all(c.as_mut(), "emb", &raw),
                    None => raw,
                };
                let scheme = scheme.get_or_insert_with(|| {
                    schemes::by_name(name, machines, 0x5eed, inputs[0].nnz().max(8)).unwrap()
                });
                let r = scheme.run_sim(&inputs, &net, &mut scratch);
                schemes::verify_outputs(&r, &inputs);
                bytes += r.report.total_bytes();
                entries += inputs.iter().map(|i| i.nnz() as u64).sum::<u64>();
                sim_time += r.report.total_time();
            }
            let wall_ns = sw.elapsed() * 1e9 / iters as f64;
            let row = Row {
                scheme: name.to_string(),
                compress: spec.label(),
                bytes_per_iter: bytes as f64 / iters as f64,
                entries_per_iter: entries as f64 / iters as f64,
                sim_time_s: sim_time / iters as f64,
                wall_ns_per_iter: wall_ns,
            };
            println!(
                "{:<12} {:<16} {:>14.0} B/iter {:>12.0} entries {:>10.6} sim-s {:>10.1} us",
                row.scheme,
                row.compress,
                row.bytes_per_iter,
                row.entries_per_iter,
                row.sim_time_s,
                wall_ns / 1e3
            );
            rows.push(row);
        }
    }

    // Headline: bytes(zen, lossless) / bytes(zen, topk) on this workload.
    let zen_bytes = |compress: &str| -> f64 {
        rows.iter()
            .find(|r| r.scheme == "zen" && r.compress == compress)
            .map(|r| r.bytes_per_iter)
            .unwrap_or(0.0)
    };
    let topk_label = CompressSpec::TopK(k as f64).label();
    let ratio = zen_bytes("none") / zen_bytes(&topk_label).max(1.0);
    println!("zen byte reduction at top-k 1% of entries: {ratio:.2}x");

    let mut json = String::new();
    json.push_str("{\n  \"pr\": 9,\n");
    json.push_str(&format!(
        "  \"config\": {{\"tiny\": {}, \"iters\": {iters}, \"machines\": {machines}, \
         \"profile\": \"NMT\", \"profile_scale\": {scale}, \"dense_len\": {dense_len}, \
         \"nnz_per_worker\": {nnz}, \"topk_k\": {k}, \"threshold\": {median}}},\n",
        cfg.tiny
    ));
    json.push_str("  \"rows\": [\n");
    let jrows: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"scheme\": \"{}\", \"compress\": \"{}\", \"bytes_per_iter\": {:.1}, \
                 \"entries_per_iter\": {:.1}, \"sim_time_s\": {:.9}, \
                 \"wall_ns_per_iter\": {:.1}}}",
                r.scheme, r.compress, r.bytes_per_iter, r.entries_per_iter, r.sim_time_s,
                r.wall_ns_per_iter
            )
        })
        .collect();
    json.push_str(&jrows.join(",\n"));
    json.push_str(&format!(
        "\n  ],\n  \"zen_topk_byte_reduction\": {ratio:.3}\n}}\n"
    ));
    std::fs::write(&cfg.out, &json).expect("write bench json");
    println!("wrote {}", cfg.out);

    if !(ratio >= 5.0) {
        eprintln!(
            "warning: zen top-k byte reduction {ratio:.2}x below the 5x acceptance line — \
             noisy run or compression regression; see tests/compress_integration.rs"
        );
    }
}
