//! Reproducible perf trajectory: the scheme × density × machines grid
//! plus the Zen partition+encode microbench, emitted as machine-readable
//! `BENCH_PR2.json` so every future PR is measured against this one.
//!
//!   cargo run --release --example bench_sync -- [--tiny] [--iters K] [--out PATH]
//!
//! - `--tiny`: CI smoke configuration (small tensors, few iterations).
//! - `--iters K`: timed iterations per cell (median reported).
//! - `--out PATH`: output JSON path (default `BENCH_PR2.json`).
//!
//! The microbench section records, in the same file, the pre-refactor
//! baseline (allocating `partition` + `encode` per iteration, fresh
//! buffers each time — the PR-1 hot path) and the scratch-arena path
//! (`partition_into` + `encode_into` + reused frame buffer), so the
//! speedup claim of ISSUE 2 is re-measurable on any machine.

use zen::cluster::{LinkKind, Network};
use zen::hashing::{HashBitmapCodec, HashBitmapPayload, HierarchicalHasher, PartitionScratch};
use zen::schemes::{self, SyncScheme, SyncScratch};
use zen::tensor::CooTensor;
use zen::util::{Pcg64, Stopwatch, Summary};
use zen::wire::encode_pull_hash_bitmap;

struct Config {
    tiny: bool,
    iters: usize,
    warmup: usize,
    out: String,
}

fn parse_args() -> Config {
    let mut cfg = Config {
        tiny: false,
        iters: 7,
        warmup: 2,
        out: "BENCH_PR2.json".to_string(),
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--tiny" => {
                cfg.tiny = true;
                cfg.iters = 3;
                cfg.warmup = 1;
            }
            "--iters" => {
                cfg.iters = args
                    .next()
                    .and_then(|v| v.parse().ok())
                    .expect("--iters needs a positive integer");
            }
            "--out" => {
                cfg.out = args.next().expect("--out needs a path");
            }
            other => panic!("unknown argument {other}"),
        }
    }
    cfg
}

fn random_inputs(seed: u64, n: usize, dense_len: usize, density: f64) -> Vec<CooTensor> {
    let nnz = ((dense_len as f64 * density) as usize).clamp(1, dense_len);
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| {
            let mut idx: Vec<u32> = rng
                .sample_distinct(dense_len, nnz)
                .into_iter()
                .map(|i| i as u32)
                .collect();
            idx.sort_unstable();
            let vals: Vec<f32> = (0..nnz).map(|_| rng.next_f32() * 2.0 - 0.99).collect();
            CooTensor::from_sorted(dense_len, idx, vals)
        })
        .collect()
}

fn median_ns<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        s.add(sw.elapsed() * 1e9);
    }
    s.median()
}

fn json_f(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.1}")
    } else {
        "null".to_string()
    }
}

fn main() {
    let cfg = parse_args();
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"pr\": 2,\n");
    json.push_str(&format!(
        "  \"config\": {{\"tiny\": {}, \"iters\": {}, \"warmup\": {}}},\n",
        cfg.tiny, cfg.iters, cfg.warmup
    ));

    // ---- Microbench: Zen hash partition + hash-bitmap encode --------
    // baseline = a faithful re-creation of the pre-refactor (PR 1)
    //            algorithm, embedded below in `mod baseline` (fresh
    //            Vec-of-pairs buckets, Mutex-collected results, 16-bit
    //            radix with fresh 512 KiB count tables, per-element
    //            frame writes) — so the recorded speedup always compares
    //            against the code this PR replaced, not against itself;
    // scratch  = the arena path (reused buffers, bulk frame writes).
    // Both run on a single-worker pool: the comparison isolates the
    // allocation/codec work; the thread-parallel win shows up in the
    // grid section (default pools).
    let (dense_len, density, n) = if cfg.tiny {
        (1 << 14, 0.02, 4)
    } else {
        (1 << 20, 0.01, 8)
    };
    let micro_inputs = random_inputs(7, 1, dense_len, density);
    let t = &micro_inputs[0];
    let hasher = HierarchicalHasher::with_defaults(42, n, t.nnz())
        .with_pool(zen::util::ThreadPool::with_workers(1));
    let domains = hasher.partition_domains(dense_len);
    let codecs: Vec<HashBitmapCodec> = domains.iter().map(|d| HashBitmapCodec::new(d)).collect();

    let baseline_ns = median_ns(cfg.warmup, cfg.iters, || {
        let parts = baseline::partition(&hasher, t);
        for (p, part) in parts.iter().enumerate() {
            let (bitmap, values) = baseline::encode(&domains[p], part);
            let frame = baseline::frame_pull(p as u32, &bitmap, &values);
            std::hint::black_box(frame.len());
        }
    });

    let mut scratch = PartitionScratch::new();
    let mut payload = HashBitmapPayload::default();
    let mut frame: Vec<u8> = Vec::new();
    let scratch_ns = median_ns(cfg.warmup, cfg.iters, || {
        hasher.partition_into(t, &mut scratch);
        frame.clear();
        for (p, codec) in codecs.iter().enumerate() {
            codec.encode_into(scratch.part(p), &mut payload);
            encode_pull_hash_bitmap(p as u32, &payload.bitmap, &payload.values, &mut frame);
        }
        std::hint::black_box(frame.len());
    });

    // Cross-check: the two paths must produce identical partitions.
    {
        let base = baseline::partition(&hasher, t);
        let mut check = PartitionScratch::new();
        hasher.partition_into(t, &mut check);
        for (p, b) in base.iter().enumerate() {
            assert_eq!(check.part(p).indices, &b.indices[..], "partition {p} diverged");
        }
    }

    let speedup = baseline_ns / scratch_ns;
    println!(
        "microbench zen_partition_encode: baseline {:.2} ms, scratch {:.2} ms, speedup {:.2}x",
        baseline_ns / 1e6,
        scratch_ns / 1e6,
        speedup
    );
    json.push_str("  \"microbench\": {\n");
    json.push_str("    \"name\": \"zen_partition_encode\",\n");
    json.push_str(&format!(
        "    \"machines\": {n}, \"dense_len\": {dense_len}, \"nnz\": {},\n",
        t.nnz()
    ));
    json.push_str(&format!(
        "    \"baseline_ns_median\": {}, \"scratch_ns_median\": {}, \"speedup\": {}\n",
        json_f(baseline_ns),
        json_f(scratch_ns),
        if speedup.is_finite() {
            format!("{speedup:.3}")
        } else {
            "null".to_string()
        }
    ));
    json.push_str("  },\n");

    // ---- Grid: scheme × density × machines --------------------------
    let grid_dense_len = if cfg.tiny { 1 << 13 } else { 1 << 18 };
    let densities: &[f64] = if cfg.tiny {
        &[0.01]
    } else {
        &[0.001, 0.01, 0.05]
    };
    let machine_counts: &[usize] = if cfg.tiny { &[4] } else { &[4, 8] };
    let scheme_names = [
        "zen",
        "zen-coo",
        "sparseps",
        "omnireduce",
        "sparcml",
        "agsparse",
        "dense",
    ];

    json.push_str("  \"grid\": [\n");
    let mut rows: Vec<String> = Vec::new();
    for &machines in machine_counts {
        for &density in densities {
            let inputs = random_inputs(1000 + machines as u64, machines, grid_dense_len, density);
            let net = Network::new(machines, LinkKind::Tcp25);
            let nnz = inputs[0].nnz();
            for name in scheme_names {
                let scheme = schemes::by_name(name, machines, 0x5eed, nnz).unwrap();
                let mut scratch = SyncScratch::new();
                let mut bytes = 0u64;
                let mut compute_overhead = 0.0f64;
                let ns = median_ns(cfg.warmup, cfg.iters, || {
                    let r = scheme.run_sim(&inputs, &net, &mut scratch);
                    bytes = r.report.total_bytes();
                    compute_overhead = r.report.compute_overhead;
                    std::hint::black_box(r.outputs.len());
                });
                println!(
                    "{:<12} m={machines} d={density:<6} {:>10.1} us/iter  {:>12} B/iter",
                    scheme.name(),
                    ns / 1e3,
                    bytes
                );
                rows.push(format!(
                    "    {{\"scheme\": \"{}\", \"machines\": {machines}, \"density\": {density}, \
                     \"dense_len\": {grid_dense_len}, \"nnz_per_worker\": {nnz}, \
                     \"ns_per_iter_median\": {}, \"bytes_per_iter\": {bytes}, \
                     \"compute_overhead_s\": {:.9}}}",
                    scheme.name(),
                    json_f(ns),
                    compute_overhead
                ));
            }
        }
    }
    json.push_str(&rows.join(",\n"));
    json.push_str("\n  ]\n}\n");

    std::fs::write(&cfg.out, &json).expect("write bench json");
    println!("wrote {}", cfg.out);
    // A measurement tool, not a gate: on tiny/noisy runs the microbench
    // can jitter below 1.0x — flag it loudly, but exit 0 so the JSON
    // this run exists to record is never discarded.
    if speedup.is_nan() || speedup <= 1.0 {
        eprintln!(
            "warning: scratch path not faster than baseline ({speedup:.2}x) — \
             noisy run or perf regression; compare BENCH_*.json across runs"
        );
    }
}

/// Faithful re-creation of the pre-refactor (PR 1) hot path, frozen
/// here so `BENCH_*.json` always records the speedup against the code
/// this PR replaced — the library's `partition()`/`encode()` wrappers
/// now run the new algorithm internally, so benchmarking them would
/// compare the refactor against itself. Kept behavior-identical:
/// fresh `Vec<(u32, f32)>` buckets per call, fresh `r1` slot arrays,
/// `Mutex<Option<_>>`-collected partition results, a 16-bit-digit LSD
/// radix sort allocating its two 256 KiB count tables per call, fresh
/// bitmap + value vectors per encode, and per-element little-endian
/// frame writes.
mod baseline {
    use std::sync::Mutex;

    use zen::hashing::HierarchicalHasher;
    use zen::tensor::{Bitmap, CooTensor};

    pub fn partition(h: &HierarchicalHasher, t: &CooTensor) -> Vec<CooTensor> {
        let n = h.n;
        let nnz = t.nnz();
        let mut buckets: Vec<Vec<(u32, f32)>> = (0..n)
            .map(|_| Vec::with_capacity(nnz / n + 16))
            .collect();
        for (&idx, &val) in t.indices.iter().zip(t.values.iter()) {
            buckets[h.family().partition(idx, n)].push((idx, val));
        }
        let results: Vec<Mutex<Option<CooTensor>>> = (0..n).map(|_| Mutex::new(None)).collect();
        for (p, bucket) in buckets.iter().enumerate() {
            let mut slots = vec![0u32; h.r1];
            let mut serial: Vec<u32> = Vec::new();
            for (e, &(idx, _)) in bucket.iter().enumerate() {
                let mut placed = false;
                for round in 1..=h.k {
                    let q = h.family().slot(round, idx, h.r1);
                    if slots[q] == 0 {
                        slots[q] = e as u32 + 1;
                        placed = true;
                        break;
                    }
                }
                if !placed {
                    serial.push(e as u32 + 1);
                }
            }
            let mut idxs: Vec<u32> = Vec::with_capacity(bucket.len());
            let mut vals: Vec<f32> = Vec::with_capacity(bucket.len());
            for &v in slots.iter().chain(serial.iter()) {
                if v != 0 {
                    let (idx, val) = bucket[(v - 1) as usize];
                    idxs.push(idx);
                    vals.push(val);
                }
            }
            radix_sort_pairs_16bit(&mut idxs, &mut vals);
            *results[p].lock().unwrap() = Some(CooTensor::from_sorted(t.dense_len, idxs, vals));
        }
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().unwrap())
            .collect()
    }

    fn radix_sort_pairs_16bit(keys: &mut Vec<u32>, vals: &mut Vec<f32>) {
        let n = keys.len();
        if n <= 64 {
            let mut pairs: Vec<(u32, f32)> =
                keys.iter().copied().zip(vals.iter().copied()).collect();
            pairs.sort_unstable_by_key(|p| p.0);
            for (i, (k, v)) in pairs.into_iter().enumerate() {
                keys[i] = k;
                vals[i] = v;
            }
            return;
        }
        let mut kbuf = vec![0u32; n];
        let mut vbuf = vec![0f32; n];
        for pass in 0..2 {
            let shift = pass * 16;
            let mut counts = vec![0u32; 1 << 16];
            for &k in keys.iter() {
                counts[((k >> shift) & 0xFFFF) as usize] += 1;
            }
            if counts.iter().any(|&c| c as usize == n) {
                continue;
            }
            let mut offsets = vec![0u32; 1 << 16];
            let mut acc = 0u32;
            for (o, &c) in offsets.iter_mut().zip(counts.iter()) {
                *o = acc;
                acc += c;
            }
            for i in 0..n {
                let b = ((keys[i] >> shift) & 0xFFFF) as usize;
                let dst = offsets[b] as usize;
                offsets[b] += 1;
                kbuf[dst] = keys[i];
                vbuf[dst] = vals[i];
            }
            std::mem::swap(keys, &mut kbuf);
            std::mem::swap(vals, &mut vbuf);
        }
    }

    pub fn encode(domain: &[u32], t: &CooTensor) -> (Bitmap, Vec<f32>) {
        let mut bitmap = Bitmap::zeros(domain.len());
        let mut values = Vec::with_capacity(t.nnz());
        let mut d = 0usize;
        for (&idx, &v) in t.indices.iter().zip(t.values.iter()) {
            while d < domain.len() && domain[d] < idx {
                d += 1;
            }
            assert!(d < domain.len() && domain[d] == idx, "index outside domain");
            bitmap.set(d);
            values.push(v);
        }
        (bitmap, values)
    }

    pub fn frame_pull(server: u32, bitmap: &Bitmap, values: &[f32]) -> Vec<u8> {
        // Pre-refactor writer: fresh buffer, per-element appends.
        let mut out = Vec::new();
        out.extend_from_slice(&0x5A45u16.to_le_bytes());
        out.push(1); // version
        out.push(2); // kind
        let len_at = out.len();
        out.extend_from_slice(&0u32.to_le_bytes());
        let body_start = out.len();
        out.extend_from_slice(&server.to_le_bytes());
        out.extend_from_slice(&(bitmap.len() as u64).to_le_bytes());
        for w in bitmap.words() {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out.extend_from_slice(&(values.len() as u32).to_le_bytes());
        for v in values {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let body_len = (out.len() - body_start) as u32;
        out[len_at..len_at + 4].copy_from_slice(&body_len.to_le_bytes());
        out
    }
}
