//! Reproducible perf trajectory: the scheme × density × machines grid
//! plus the Zen partition+encode microbench, emitted as machine-readable
//! `BENCH_PR2.json` so every future PR is measured against this one.
//!
//!   cargo run --release --example bench_sync -- [--tiny] [--iters K] [--out PATH]
//!       [--out8 PATH] [--summary]
//!
//! - `--tiny`: CI smoke configuration (small tensors, few iterations).
//! - `--iters K`: timed iterations per cell (median reported).
//! - `--out PATH`: output JSON path (default `BENCH_PR2.json`).
//! - `--out8 PATH`: PR-8 output JSON path (default `BENCH_PR8.json`) —
//!   scalar-vs-chunked kernel medians plus the serialized / greedy /
//!   priority timeline comparison at n ∈ {8, 64, 256} machines
//!   (n ∈ {8} under `--tiny`) over the event transport.
//! - `--summary`: additionally render both PR-8 tables as markdown to
//!   `BENCH.md` (the committed, human-readable benchmark record).
//!
//! The microbench section records, in the same file, the pre-refactor
//! baseline (allocating `partition` + `encode` per iteration, fresh
//! buffers each time — the PR-1 hot path) and the scratch-arena path
//! (`partition_into` + `encode_into` + reused frame buffer), so the
//! speedup claim of ISSUE 2 is re-measurable on any machine.

use zen::cluster::{LinkKind, Network};
use zen::engine::{EngineConfig, SyncEngine};
use zen::hashing::{
    HashBitmapCodec, HashBitmapPayload, HashFamily, HierarchicalHasher, PartitionScratch,
};
use zen::kernel::{chunked, scalar};
use zen::planner::FixedPlanner;
use zen::schemes::{self, SyncScheme, SyncScratch};
use zen::tensor::CooTensor;
use zen::util::{Pcg64, Stopwatch, Summary};
use zen::wire::{encode_pull_hash_bitmap, TransportKind};
use zen::workload::{profiles, GradientGen};

struct Config {
    tiny: bool,
    iters: usize,
    warmup: usize,
    out: String,
    out8: String,
    summary: bool,
}

fn parse_args() -> Config {
    let mut cfg = Config {
        tiny: false,
        iters: 7,
        warmup: 2,
        out: "BENCH_PR2.json".to_string(),
        out8: "BENCH_PR8.json".to_string(),
        summary: false,
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--tiny" => {
                cfg.tiny = true;
                cfg.iters = 3;
                cfg.warmup = 1;
            }
            "--iters" => {
                cfg.iters = args
                    .next()
                    .and_then(|v| v.parse().ok())
                    .expect("--iters needs a positive integer");
            }
            "--out" => {
                cfg.out = args.next().expect("--out needs a path");
            }
            "--out8" => {
                cfg.out8 = args.next().expect("--out8 needs a path");
            }
            "--summary" => {
                cfg.summary = true;
            }
            other => panic!("unknown argument {other}"),
        }
    }
    cfg
}

fn random_inputs(seed: u64, n: usize, dense_len: usize, density: f64) -> Vec<CooTensor> {
    let nnz = ((dense_len as f64 * density) as usize).clamp(1, dense_len);
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| {
            let mut idx: Vec<u32> = rng
                .sample_distinct(dense_len, nnz)
                .into_iter()
                .map(|i| i as u32)
                .collect();
            idx.sort_unstable();
            let vals: Vec<f32> = (0..nnz).map(|_| rng.next_f32() * 2.0 - 0.99).collect();
            CooTensor::from_sorted(dense_len, idx, vals)
        })
        .collect()
}

fn median_ns<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        s.add(sw.elapsed() * 1e9);
    }
    s.median()
}

fn json_f(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.1}")
    } else {
        "null".to_string()
    }
}

fn main() {
    let cfg = parse_args();
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"pr\": 2,\n");
    json.push_str(&format!(
        "  \"config\": {{\"tiny\": {}, \"iters\": {}, \"warmup\": {}}},\n",
        cfg.tiny, cfg.iters, cfg.warmup
    ));

    // ---- Microbench: Zen hash partition + hash-bitmap encode --------
    // baseline = a faithful re-creation of the pre-refactor (PR 1)
    //            algorithm, embedded below in `mod baseline` (fresh
    //            Vec-of-pairs buckets, Mutex-collected results, 16-bit
    //            radix with fresh 512 KiB count tables, per-element
    //            frame writes) — so the recorded speedup always compares
    //            against the code this PR replaced, not against itself;
    // scratch  = the arena path (reused buffers, bulk frame writes).
    // Both run on a single-worker pool: the comparison isolates the
    // allocation/codec work; the thread-parallel win shows up in the
    // grid section (default pools).
    let (dense_len, density, n) = if cfg.tiny {
        (1 << 14, 0.02, 4)
    } else {
        (1 << 20, 0.01, 8)
    };
    let micro_inputs = random_inputs(7, 1, dense_len, density);
    let t = &micro_inputs[0];
    let hasher = HierarchicalHasher::with_defaults(42, n, t.nnz())
        .with_pool(zen::util::ThreadPool::with_workers(1));
    let domains = hasher.partition_domains(dense_len);
    let codecs: Vec<HashBitmapCodec> = domains.iter().map(|d| HashBitmapCodec::new(d)).collect();

    let baseline_ns = median_ns(cfg.warmup, cfg.iters, || {
        let parts = baseline::partition(&hasher, t);
        for (p, part) in parts.iter().enumerate() {
            let (bitmap, values) = baseline::encode(&domains[p], part);
            let frame = baseline::frame_pull(p as u32, &bitmap, &values);
            std::hint::black_box(frame.len());
        }
    });

    let mut scratch = PartitionScratch::new();
    let mut payload = HashBitmapPayload::default();
    let mut frame: Vec<u8> = Vec::new();
    let scratch_ns = median_ns(cfg.warmup, cfg.iters, || {
        hasher.partition_into(t, &mut scratch);
        frame.clear();
        for (p, codec) in codecs.iter().enumerate() {
            codec.encode_into(scratch.part(p), &mut payload);
            encode_pull_hash_bitmap(p as u32, &payload.bitmap, &payload.values, &mut frame);
        }
        std::hint::black_box(frame.len());
    });

    // Cross-check: the two paths must produce identical partitions.
    {
        let base = baseline::partition(&hasher, t);
        let mut check = PartitionScratch::new();
        hasher.partition_into(t, &mut check);
        for (p, b) in base.iter().enumerate() {
            assert_eq!(check.part(p).indices, &b.indices[..], "partition {p} diverged");
        }
    }

    let speedup = baseline_ns / scratch_ns;
    println!(
        "microbench zen_partition_encode: baseline {:.2} ms, scratch {:.2} ms, speedup {:.2}x",
        baseline_ns / 1e6,
        scratch_ns / 1e6,
        speedup
    );
    json.push_str("  \"microbench\": {\n");
    json.push_str("    \"name\": \"zen_partition_encode\",\n");
    json.push_str(&format!(
        "    \"machines\": {n}, \"dense_len\": {dense_len}, \"nnz\": {},\n",
        t.nnz()
    ));
    json.push_str(&format!(
        "    \"baseline_ns_median\": {}, \"scratch_ns_median\": {}, \"speedup\": {}\n",
        json_f(baseline_ns),
        json_f(scratch_ns),
        if speedup.is_finite() {
            format!("{speedup:.3}")
        } else {
            "null".to_string()
        }
    ));
    json.push_str("  },\n");

    // ---- Grid: scheme × density × machines --------------------------
    let grid_dense_len = if cfg.tiny { 1 << 13 } else { 1 << 18 };
    let densities: &[f64] = if cfg.tiny {
        &[0.01]
    } else {
        &[0.001, 0.01, 0.05]
    };
    let machine_counts: &[usize] = if cfg.tiny { &[4] } else { &[4, 8] };
    let scheme_names = [
        "zen",
        "zen-coo",
        "sparseps",
        "omnireduce",
        "sparcml",
        "agsparse",
        "dense",
    ];

    json.push_str("  \"grid\": [\n");
    let mut rows: Vec<String> = Vec::new();
    for &machines in machine_counts {
        for &density in densities {
            let inputs = random_inputs(1000 + machines as u64, machines, grid_dense_len, density);
            let net = Network::new(machines, LinkKind::Tcp25);
            let nnz = inputs[0].nnz();
            for name in scheme_names {
                let scheme = schemes::by_name(name, machines, 0x5eed, nnz).unwrap();
                let mut scratch = SyncScratch::new();
                let mut bytes = 0u64;
                let mut compute_overhead = 0.0f64;
                let ns = median_ns(cfg.warmup, cfg.iters, || {
                    let r = scheme.run_sim(&inputs, &net, &mut scratch);
                    bytes = r.report.total_bytes();
                    compute_overhead = r.report.compute_overhead;
                    std::hint::black_box(r.outputs.len());
                });
                println!(
                    "{:<12} m={machines} d={density:<6} {:>10.1} us/iter  {:>12} B/iter",
                    scheme.name(),
                    ns / 1e3,
                    bytes
                );
                rows.push(format!(
                    "    {{\"scheme\": \"{}\", \"machines\": {machines}, \"density\": {density}, \
                     \"dense_len\": {grid_dense_len}, \"nnz_per_worker\": {nnz}, \
                     \"ns_per_iter_median\": {}, \"bytes_per_iter\": {bytes}, \
                     \"compute_overhead_s\": {:.9}}}",
                    scheme.name(),
                    json_f(ns),
                    compute_overhead
                ));
            }
        }
    }
    json.push_str(&rows.join(",\n"));
    json.push_str("\n  ]\n}\n");

    std::fs::write(&cfg.out, &json).expect("write bench json");
    println!("wrote {}", cfg.out);

    // ---- PR 8 §1: scalar vs chunked kernel medians -------------------
    // Both implementations are always compiled (`kernel::active` only
    // picks which one the hot paths call), so the comparison below pins
    // the vectorization win — and `tests/kernel_parity.rs` pins that the
    // two are bit-identical, so this is a pure-speed table.
    let wn = if cfg.tiny { 1 << 12 } else { 1 << 16 };
    let mut krng = Pcg64::seeded(0x8888);
    let mut rand_words = |n: usize| -> Vec<u64> {
        (0..n)
            .map(|_| ((krng.next_u32() as u64) << 32) | krng.next_u32() as u64)
            .collect()
    };
    let wa = rand_words(wn);
    let wb = rand_words(wn);
    let merge_len = if cfg.tiny { 1 << 14 } else { 1 << 18 };
    let merge_inputs = random_inputs(0x99, 2, merge_len, 0.3);
    let (ma, mb) = (&merge_inputs[0], &merge_inputs[1]);
    let keys: Vec<u32> = ma.indices.clone();
    let domain: Vec<u32> = ma.indices.clone();
    let queries: Vec<u32> = domain.iter().copied().step_by(2).collect();
    let part8 = HashFamily::new(0x5eed, 4).partitioner(8);

    let mut krows: Vec<(&str, f64, f64)> = Vec::new();
    {
        let mut dst = wa.clone();
        let s = median_ns(cfg.warmup, cfg.iters, || {
            dst.copy_from_slice(&wa);
            scalar::or_words(&mut dst, &wb);
            std::hint::black_box(dst[0]);
        });
        let c = median_ns(cfg.warmup, cfg.iters, || {
            dst.copy_from_slice(&wa);
            chunked::or_words(&mut dst, &wb);
            std::hint::black_box(dst[0]);
        });
        krows.push(("or_words", s, c));
    }
    {
        let s = median_ns(cfg.warmup, cfg.iters, || {
            std::hint::black_box(scalar::and_count_words(&wa, &wb));
        });
        let c = median_ns(cfg.warmup, cfg.iters, || {
            std::hint::black_box(chunked::and_count_words(&wa, &wb));
        });
        krows.push(("and_count_words", s, c));
    }
    {
        let s = median_ns(cfg.warmup, cfg.iters, || {
            std::hint::black_box(scalar::count_ones_words(&wa));
        });
        let c = median_ns(cfg.warmup, cfg.iters, || {
            std::hint::black_box(chunked::count_ones_words(&wa));
        });
        krows.push(("count_ones_words", s, c));
    }
    {
        let (mut oi, mut ov) = (Vec::new(), Vec::new());
        let s = median_ns(cfg.warmup, cfg.iters, || {
            oi.clear();
            ov.clear();
            scalar::merge_sorted(&ma.indices, &ma.values, &mb.indices, &mb.values, &mut oi, &mut ov);
            std::hint::black_box(oi.len());
        });
        let c = median_ns(cfg.warmup, cfg.iters, || {
            oi.clear();
            ov.clear();
            chunked::merge_sorted(&ma.indices, &ma.values, &mb.indices, &mb.values, &mut oi, &mut ov);
            std::hint::black_box(oi.len());
        });
        krows.push(("merge_sorted", s, c));
    }
    {
        let mut counts = [0u32; 256];
        let s = median_ns(cfg.warmup, cfg.iters, || {
            scalar::histogram_u8(&keys, 8, &mut counts);
            std::hint::black_box(counts[0]);
        });
        let c = median_ns(cfg.warmup, cfg.iters, || {
            chunked::histogram_u8(&keys, 8, &mut counts);
            std::hint::black_box(counts[0]);
        });
        krows.push(("histogram_u8", s, c));
    }
    {
        let s = median_ns(cfg.warmup, cfg.iters, || {
            let mut d = 0usize;
            for &q in &queries {
                d = scalar::domain_rank(&domain, d, q);
            }
            std::hint::black_box(d);
        });
        let c = median_ns(cfg.warmup, cfg.iters, || {
            let mut d = 0usize;
            for &q in &queries {
                d = chunked::domain_rank(&domain, d, q);
            }
            std::hint::black_box(d);
        });
        krows.push(("domain_rank", s, c));
    }
    {
        let s = median_ns(cfg.warmup, cfg.iters, || {
            let mut acc = 0u64;
            scalar::partition_scatter(
                |i| part8.partition(i),
                &ma.indices,
                &ma.values,
                |p, i, _v| acc = acc.wrapping_add(p as u64 ^ i as u64),
            );
            std::hint::black_box(acc);
        });
        let c = median_ns(cfg.warmup, cfg.iters, || {
            let mut acc = 0u64;
            chunked::partition_scatter(
                |i| part8.partition(i),
                &ma.indices,
                &ma.values,
                |p, i, _v| acc = acc.wrapping_add(p as u64 ^ i as u64),
            );
            std::hint::black_box(acc);
        });
        krows.push(("partition_scatter", s, c));
    }
    for (name, s, c) in &krows {
        println!(
            "kernel {name:<18} scalar {:>9.1} us  chunked {:>9.1} us  {:>5.2}x",
            s / 1e3,
            c / 1e3,
            s / c
        );
    }

    // ---- PR 8 §2: serialized vs greedy vs priority timelines ---------
    // NMT profile (scaled), event transport (classed intra/inter
    // resources), one engine run per variant — the timeline metrics are
    // virtual-time and deterministic, so no repetition is needed.
    let machine_counts8: &[usize] = if cfg.tiny { &[8] } else { &[8, 64, 256] };
    let scale = if cfg.tiny { 2048 } else { 512 };
    let profile = profiles::by_name("nmt").unwrap().scaled(scale);
    let gen = GradientGen::new(profile, 0x817);
    let specs8 = gen.layer_specs(4, 4);
    let bucket_bytes = if cfg.tiny { 16 * 1024 } else { 64 * 1024 };
    struct TimelineRow {
        machines: usize,
        buckets: usize,
        serialized: f64,
        greedy_overlapped: f64,
        priority_overlapped: f64,
        greedy_forward_finish: f64,
        priority_forward_finish: f64,
    }
    let mut trows: Vec<TimelineRow> = Vec::new();
    for &m in machine_counts8 {
        let layers = gen.layer_iteration_all(&specs8, 1, m);
        let net = Network::new(m, LinkKind::Tcp25);
        let planner =
            FixedPlanner::new(schemes::by_name("zen", m, 0x5eed, gen.expected_nnz()).unwrap());
        let base = EngineConfig::new(bucket_bytes, 0.05).with_transport(TransportKind::Event);
        let greedy = SyncEngine::new(base.clone())
            .run(&specs8, &layers, &planner, &net, |r| r.comm_time());
        let prio = SyncEngine::new(base.with_priority(true))
            .run(&specs8, &layers, &planner, &net, |r| r.comm_time());
        println!(
            "timeline n={m:<4} buckets={:<3} serialized {:.4}s  greedy {:.4}s  priority {:.4}s  \
             fwd-finish {:.4}s -> {:.4}s",
            greedy.buckets.len(),
            greedy.serialized_time,
            greedy.overlapped_time,
            prio.overlapped_time,
            greedy.forward_finish,
            prio.forward_finish
        );
        trows.push(TimelineRow {
            machines: m,
            buckets: greedy.buckets.len(),
            serialized: greedy.serialized_time,
            greedy_overlapped: greedy.overlapped_time,
            priority_overlapped: prio.overlapped_time,
            greedy_forward_finish: greedy.forward_finish,
            priority_forward_finish: prio.forward_finish,
        });
    }

    let mut j8 = String::new();
    j8.push_str("{\n  \"pr\": 8,\n");
    j8.push_str(&format!(
        "  \"config\": {{\"tiny\": {}, \"iters\": {}, \"warmup\": {}, \"kernel_words\": {wn}, \
         \"merge_dense_len\": {merge_len}, \"bucket_bytes\": {bucket_bytes}, \
         \"profile_scale\": {scale}}},\n",
        cfg.tiny, cfg.iters, cfg.warmup
    ));
    j8.push_str("  \"kernels\": [\n");
    let kjson: Vec<String> = krows
        .iter()
        .map(|(name, s, c)| {
            format!(
                "    {{\"kernel\": \"{name}\", \"scalar_ns_median\": {}, \
                 \"chunked_ns_median\": {}, \"speedup\": {}}}",
                json_f(*s),
                json_f(*c),
                if (s / c).is_finite() {
                    format!("{:.3}", s / c)
                } else {
                    "null".to_string()
                }
            )
        })
        .collect();
    j8.push_str(&kjson.join(",\n"));
    j8.push_str("\n  ],\n  \"timeline\": [\n");
    let tjson: Vec<String> = trows
        .iter()
        .map(|r| {
            format!(
                "    {{\"machines\": {}, \"buckets\": {}, \"serialized_s\": {:.6}, \
                 \"greedy_overlapped_s\": {:.6}, \"priority_overlapped_s\": {:.6}, \
                 \"greedy_forward_finish_s\": {:.6}, \"priority_forward_finish_s\": {:.6}}}",
                r.machines,
                r.buckets,
                r.serialized,
                r.greedy_overlapped,
                r.priority_overlapped,
                r.greedy_forward_finish,
                r.priority_forward_finish
            )
        })
        .collect();
    j8.push_str(&tjson.join(",\n"));
    j8.push_str("\n  ]\n}\n");
    std::fs::write(&cfg.out8, &j8).expect("write PR8 bench json");
    println!("wrote {}", cfg.out8);

    if cfg.summary {
        let mut md = String::new();
        md.push_str("# BENCH.md — measured performance record\n\n");
        md.push_str(&format!(
            "Generated by `cargo run --release --example bench_sync -- --summary`\n\
             (iters = {}, warmup = {}, tiny = {}). Raw data: `BENCH_PR2.json`,\n\
             `BENCH_PR8.json`. Times are wall-clock medians for kernels and\n\
             deterministic virtual seconds for timelines.\n\n",
            cfg.iters, cfg.warmup, cfg.tiny
        ));
        md.push_str("## Kernel layer: scalar vs chunked (PR 8)\n\n");
        md.push_str(&format!(
            "{wn} words per bitmap kernel; merge/scatter over dense_len = {merge_len}, \
             density 0.3.\n\n"
        ));
        md.push_str("| kernel | scalar (us) | chunked (us) | speedup |\n");
        md.push_str("|---|---:|---:|---:|\n");
        for (name, s, c) in &krows {
            md.push_str(&format!(
                "| `{name}` | {:.1} | {:.1} | {:.2}x |\n",
                s / 1e3,
                c / 1e3,
                s / c
            ));
        }
        md.push_str(
            "\nBit-identity between the two implementations is enforced by\n\
             `tests/kernel_parity.rs`; the `scalar_kernels` Cargo feature swaps the\n\
             hot paths back to the scalar forms.\n\n",
        );
        md.push_str("## Priority scheduling: serialized vs greedy vs priority (PR 8)\n\n");
        md.push_str(&format!(
            "NMT profile scaled 1/{scale}, zen scheme, event transport, bucket\n\
             threshold {bucket_bytes} B, compute 0.05 s, forward 0.025 s. `fwd-finish`\n\
             is when the *next* iteration's forward pass clears its last blocked\n\
             layer — the metric priority scheduling improves.\n\n"
        ));
        md.push_str(
            "| n | buckets | serialized (s) | greedy (s) | priority (s) | \
             greedy fwd-finish (s) | priority fwd-finish (s) |\n",
        );
        md.push_str("|---:|---:|---:|---:|---:|---:|---:|\n");
        for r in &trows {
            md.push_str(&format!(
                "| {} | {} | {:.4} | {:.4} | {:.4} | {:.4} | {:.4} |\n",
                r.machines,
                r.buckets,
                r.serialized,
                r.greedy_overlapped,
                r.priority_overlapped,
                r.greedy_forward_finish,
                r.priority_forward_finish
            ));
        }
        md.push_str(
            "\nAcceptance: priority overlapped time must be ≤ greedy on every row\n\
             and strictly better on at least one multi-bucket row; both are ≤ the\n\
             serialized time by construction.\n\
             \n\
             ## Scratch-arena microbench and scheme grid (PR 2)\n\
             \n\
             See `BENCH_PR2.json` (same binary, `--out` section): the frozen\n\
             pre-refactor baseline vs the arena path, and the scheme × density ×\n\
             machines grid.\n",
        );
        std::fs::write("BENCH.md", &md).expect("write BENCH.md");
        println!("wrote BENCH.md");
    }

    // A measurement tool, not a gate: on tiny/noisy runs the microbench
    // can jitter below 1.0x — flag it loudly, but exit 0 so the JSON
    // this run exists to record is never discarded.
    if speedup.is_nan() || speedup <= 1.0 {
        eprintln!(
            "warning: scratch path not faster than baseline ({speedup:.2}x) — \
             noisy run or perf regression; compare BENCH_*.json across runs"
        );
    }
}

/// Faithful re-creation of the pre-refactor (PR 1) hot path, frozen
/// here so `BENCH_*.json` always records the speedup against the code
/// this PR replaced — the library's `partition()`/`encode()` wrappers
/// now run the new algorithm internally, so benchmarking them would
/// compare the refactor against itself. Kept behavior-identical:
/// fresh `Vec<(u32, f32)>` buckets per call, fresh `r1` slot arrays,
/// `Mutex<Option<_>>`-collected partition results, a 16-bit-digit LSD
/// radix sort allocating its two 256 KiB count tables per call, fresh
/// bitmap + value vectors per encode, and per-element little-endian
/// frame writes.
mod baseline {
    use std::sync::Mutex;

    use zen::hashing::HierarchicalHasher;
    use zen::tensor::{Bitmap, CooTensor};

    pub fn partition(h: &HierarchicalHasher, t: &CooTensor) -> Vec<CooTensor> {
        let n = h.n;
        let nnz = t.nnz();
        let mut buckets: Vec<Vec<(u32, f32)>> = (0..n)
            .map(|_| Vec::with_capacity(nnz / n + 16))
            .collect();
        for (&idx, &val) in t.indices.iter().zip(t.values.iter()) {
            buckets[h.family().partition(idx, n)].push((idx, val));
        }
        let results: Vec<Mutex<Option<CooTensor>>> = (0..n).map(|_| Mutex::new(None)).collect();
        for (p, bucket) in buckets.iter().enumerate() {
            let mut slots = vec![0u32; h.r1];
            let mut serial: Vec<u32> = Vec::new();
            for (e, &(idx, _)) in bucket.iter().enumerate() {
                let mut placed = false;
                for round in 1..=h.k {
                    let q = h.family().slot(round, idx, h.r1);
                    if slots[q] == 0 {
                        slots[q] = e as u32 + 1;
                        placed = true;
                        break;
                    }
                }
                if !placed {
                    serial.push(e as u32 + 1);
                }
            }
            let mut idxs: Vec<u32> = Vec::with_capacity(bucket.len());
            let mut vals: Vec<f32> = Vec::with_capacity(bucket.len());
            for &v in slots.iter().chain(serial.iter()) {
                if v != 0 {
                    let (idx, val) = bucket[(v - 1) as usize];
                    idxs.push(idx);
                    vals.push(val);
                }
            }
            radix_sort_pairs_16bit(&mut idxs, &mut vals);
            *results[p].lock().unwrap() = Some(CooTensor::from_sorted(t.dense_len, idxs, vals));
        }
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().unwrap())
            .collect()
    }

    fn radix_sort_pairs_16bit(keys: &mut Vec<u32>, vals: &mut Vec<f32>) {
        let n = keys.len();
        if n <= 64 {
            let mut pairs: Vec<(u32, f32)> =
                keys.iter().copied().zip(vals.iter().copied()).collect();
            pairs.sort_unstable_by_key(|p| p.0);
            for (i, (k, v)) in pairs.into_iter().enumerate() {
                keys[i] = k;
                vals[i] = v;
            }
            return;
        }
        let mut kbuf = vec![0u32; n];
        let mut vbuf = vec![0f32; n];
        for pass in 0..2 {
            let shift = pass * 16;
            let mut counts = vec![0u32; 1 << 16];
            for &k in keys.iter() {
                counts[((k >> shift) & 0xFFFF) as usize] += 1;
            }
            if counts.iter().any(|&c| c as usize == n) {
                continue;
            }
            let mut offsets = vec![0u32; 1 << 16];
            let mut acc = 0u32;
            for (o, &c) in offsets.iter_mut().zip(counts.iter()) {
                *o = acc;
                acc += c;
            }
            for i in 0..n {
                let b = ((keys[i] >> shift) & 0xFFFF) as usize;
                let dst = offsets[b] as usize;
                offsets[b] += 1;
                kbuf[dst] = keys[i];
                vbuf[dst] = vals[i];
            }
            std::mem::swap(keys, &mut kbuf);
            std::mem::swap(vals, &mut vbuf);
        }
    }

    pub fn encode(domain: &[u32], t: &CooTensor) -> (Bitmap, Vec<f32>) {
        let mut bitmap = Bitmap::zeros(domain.len());
        let mut values = Vec::with_capacity(t.nnz());
        let mut d = 0usize;
        for (&idx, &v) in t.indices.iter().zip(t.values.iter()) {
            while d < domain.len() && domain[d] < idx {
                d += 1;
            }
            assert!(d < domain.len() && domain[d] == idx, "index outside domain");
            bitmap.set(d);
            values.push(v);
        }
        (bitmap, values)
    }

    pub fn frame_pull(server: u32, bitmap: &Bitmap, values: &[f32]) -> Vec<u8> {
        // Pre-refactor writer: fresh buffer, per-element appends.
        let mut out = Vec::new();
        out.extend_from_slice(&0x5A45u16.to_le_bytes());
        out.push(1); // version
        out.push(2); // kind
        let len_at = out.len();
        out.extend_from_slice(&0u32.to_le_bytes());
        let body_start = out.len();
        out.extend_from_slice(&server.to_le_bytes());
        out.extend_from_slice(&(bitmap.len() as u64).to_le_bytes());
        for w in bitmap.words() {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out.extend_from_slice(&(values.len() as u32).to_le_bytes());
        for v in values {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let body_len = (out.len() - body_start) as u32;
        out[len_at..len_at + 4].copy_from_slice(&body_len.to_le_bytes());
        out
    }
}
