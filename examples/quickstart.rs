//! Quickstart: synchronize one sparse gradient tensor across 8 simulated
//! machines with every scheme and compare traffic, time, and balance.
//!
//!   cargo run --release --example quickstart

use zen::cluster::{LinkKind, Network};
use zen::schemes::{self, verify_outputs, SyncScheme, SyncScratch};
use zen::util::human_bytes;
use zen::workload::{profiles, GradientGen};

fn main() {
    let machines = 8;
    // An NMT-profile gradient tensor, scaled to laptop size.
    let profile = profiles::by_name("NMT").unwrap().scaled(256);
    let gen = GradientGen::new(profile.clone(), 42);
    let inputs = gen.iteration_all(0, machines);
    println!(
        "tensor: {} params, per-worker density {:.2}% ({} non-zeros)",
        profile.emb_params(),
        inputs[0].density() * 100.0,
        inputs[0].nnz()
    );

    let net = Network::new(machines, LinkKind::Tcp25);
    println!(
        "\n{:<12} {:>12} {:>12} {:>10} {:>14}",
        "scheme", "traffic", "hot recv", "time(ms)", "recv imbalance"
    );
    for scheme in schemes::all_schemes(machines, 7, gen.expected_nnz()) {
        let r = scheme.run_sim(&inputs, &net, &mut SyncScratch::new());
        // every scheme must produce the exact aggregation
        verify_outputs(&r, &inputs);
        println!(
            "{:<12} {:>12} {:>12} {:>10.2} {:>14.2}",
            scheme.name(),
            human_bytes(r.report.total_bytes() as f64),
            human_bytes(r.report.max_stage_recv() as f64),
            r.report.comm_time() * 1e3,
            r.report.recv_imbalance()
        );
    }
    println!("\nall schemes verified against the dense reference sum ✓");
}
