//! Topology trajectory: flat vs topology-aware auto planning, emitted
//! as machine-readable `BENCH_PR5.json` so the tentpole's claim — the
//! planner picks different (and better) schemes once it can see the
//! two-level cluster — is re-measurable on any machine.
//!
//!   cargo run --release --example bench_topology -- [--tiny] [--out PATH]
//!
//! Each workload is planned twice with the cost planner: once against a
//! flat mesh over the inter link, once against the real 4×2 two-level
//! topology (10× faster intra-node links). Both chosen schemes then
//! *execute* on the two-level transport, and the JSON records the
//! per-link-class measured times — `topo_aware_le_flat` is the
//! acceptance signal CI uploads to the bench-trajectory artifact.

use zen::cluster::{LinkClass, LinkKind, Network, Topology};
use zen::planner::{CostPlanner, PlanConfig, Planner};
use zen::schemes::{SyncScheme, SyncScratch};
use zen::tensor::CooTensor;
use zen::workload::{group_clustered_inputs, random_uniform_inputs};

struct Config {
    tiny: bool,
    out: String,
}

fn parse_args() -> Config {
    let mut cfg = Config {
        tiny: false,
        out: "BENCH_PR5.json".to_string(),
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--tiny" => cfg.tiny = true,
            "--out" => cfg.out = args.next().expect("--out needs a path"),
            other => panic!("unknown argument {other}"),
        }
    }
    cfg
}

/// Measured comm time (plus per-class split) of one scheme on `net`.
fn run(
    scheme: &std::sync::Arc<dyn SyncScheme>,
    inputs: &[CooTensor],
    net: &Network,
) -> (f64, [f64; 2]) {
    let r = scheme.run_sim(inputs, net, &mut SyncScratch::new());
    (r.report.comm_time(), r.report.time_by_class())
}

fn main() {
    let cfg = parse_args();
    let dense_len = if cfg.tiny { 1 << 16 } else { 1 << 20 };
    let (nodes, ranks) = (4usize, 2usize);
    let n = nodes * ranks;
    let inter = LinkKind::Custom(25_000_000_000, 0);
    let intra = LinkKind::Custom(250_000_000_000, 0);
    let flat = Topology::flat(n, inter);
    let two_level = Topology::two_level(nodes, ranks, intra, inter);
    let net = Network::with_topology(two_level.clone());

    let workloads: Vec<(&str, Vec<CooTensor>)> = vec![
        (
            "group-clustered",
            group_clustered_inputs(0x5e7, 2, n / 2, dense_len, 0.01),
        ),
        ("uniform", random_uniform_inputs(0x5e8, n, dense_len, 0.01)),
        (
            "node-clustered",
            group_clustered_inputs(0x5e9, nodes, ranks, dense_len, 0.02),
        ),
    ];

    let mut rows: Vec<String> = Vec::new();
    let mut wins = 0usize;
    for (name, inputs) in &workloads {
        // Two independent planners so the caches cannot leak choices.
        let flat_planner = CostPlanner::new(n, 0xbe, 4096, PlanConfig::default());
        let topo_planner = CostPlanner::new(n, 0xbe, 4096, PlanConfig::default());
        let flat_pick = flat_planner.plan("bucket", inputs, &flat);
        let topo_pick = topo_planner.plan("bucket", inputs, &two_level);
        let flat_scheme = flat_pick.plan.as_ref().unwrap().chosen;
        let topo_scheme = topo_pick.plan.as_ref().unwrap().chosen;
        // Both choices execute on the *real* (two-level) fabric.
        let (t_flat, _) = run(&flat_pick.scheme, inputs, &net);
        let (t_topo, by_class) = run(&topo_pick.scheme, inputs, &net);
        let le = t_topo <= t_flat * 1.0001;
        wins += le as usize;
        println!(
            "{name:<16} flat-plan {flat_scheme:<10} {:>9.3}ms | topo-plan {topo_scheme:<10} \
             {:>9.3}ms (intra {:.3}ms inter {:.3}ms) | topo<=flat: {le}",
            t_flat * 1e3,
            t_topo * 1e3,
            by_class[LinkClass::Intra.idx()] * 1e3,
            by_class[LinkClass::Inter.idx()] * 1e3,
        );
        rows.push(format!(
            "    {{\"workload\": \"{name}\", \"flat_choice\": \"{flat_scheme}\", \
             \"topo_choice\": \"{topo_scheme}\", \"flat_choice_s\": {t_flat:.6e}, \
             \"topo_choice_s\": {t_topo:.6e}, \"topo_intra_s\": {:.6e}, \
             \"topo_inter_s\": {:.6e}, \"topo_aware_le_flat\": {le}}}",
            by_class[0], by_class[1]
        ));
    }

    let json = format!(
        "{{\n  \"pr\": 5,\n  \"config\": {{\"tiny\": {}, \"dense_len\": {dense_len}, \
         \"topology\": \"{}x{}\", \"inter_gbps\": 25, \"intra_gbps\": 250}},\n  \
         \"topo_wins\": {wins},\n  \"workloads\": [\n{}\n  ]\n}}\n",
        cfg.tiny,
        nodes,
        ranks,
        rows.join(",\n")
    );
    std::fs::write(&cfg.out, &json).expect("write bench json");
    println!(
        "wrote {} (topology-aware plan <= flat plan on {wins}/{} workloads)",
        cfg.out,
        workloads.len()
    );
    assert!(
        wins >= 1,
        "acceptance: topology-aware planning must match or beat flat planning somewhere"
    );
}
