"""L2 model correctness: Pallas-backed train step vs pure-jnp oracle,
gradient shapes, and a miniature convergence check."""

import numpy as np
import pytest

from compile import model


def make_batch(rng, b=8, k=3, d=16, h=24):
    return dict(
        center=rng.standard_normal((b, d)).astype(np.float32),
        context=rng.standard_normal((b, d)).astype(np.float32),
        neg=rng.standard_normal((b, k, d)).astype(np.float32),
        w1=(rng.standard_normal((d, h)) / np.sqrt(d)).astype(np.float32),
        b1=np.zeros(h, np.float32),
        w2=(rng.standard_normal((h, d)) / np.sqrt(h)).astype(np.float32),
        b2=np.zeros(d, np.float32),
    )


ARG_ORDER = ["center", "context", "neg", "w1", "b1", "w2", "b2"]


def run(fn, batch):
    return fn(*[batch[a] for a in ARG_ORDER])


def test_train_step_matches_ref():
    rng = np.random.default_rng(0)
    batch = make_batch(rng)
    got = run(model.train_step, batch)
    want = run(model.train_step_ref, batch)
    assert len(got) == len(want) == 8
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=2e-4, atol=2e-5)


def test_output_shapes():
    rng = np.random.default_rng(1)
    b, k, d, h = 8, 3, 16, 24
    batch = make_batch(rng, b, k, d, h)
    out = run(model.train_step, batch)
    assert np.asarray(out[0]).shape == ()
    assert np.asarray(out[1]).shape == (b, d)  # g_center
    assert np.asarray(out[2]).shape == (b, d)  # g_context
    assert np.asarray(out[3]).shape == (b, k, d)  # g_neg
    assert np.asarray(out[4]).shape == (d, h)  # g_w1
    assert np.asarray(out[5]).shape == (h,)
    assert np.asarray(out[6]).shape == (h, d)
    assert np.asarray(out[7]).shape == (d,)


def test_loss_positive_and_finite():
    rng = np.random.default_rng(2)
    batch = make_batch(rng)
    loss = np.asarray(run(model.train_step, batch)[0])
    assert np.isfinite(loss) and loss > 0


@pytest.mark.parametrize("seed", [3, 4])
def test_sgd_reduces_loss(seed):
    """A few SGD steps on a fixed batch must reduce the loss."""
    rng = np.random.default_rng(seed)
    batch = make_batch(rng)
    lr = 0.1
    first = None
    last = None
    for _ in range(15):
        out = run(model.train_step, batch)
        loss = float(np.asarray(out[0]))
        if first is None:
            first = loss
        last = loss
        for i, a in enumerate(ARG_ORDER):
            batch[a] = batch[a] - lr * np.asarray(out[i + 1])
    assert last < first * 0.8, f"loss {first} -> {last}"


def test_gradients_are_row_sparse_signal():
    """Gradient rows must be non-trivial (the sparse sync has content)."""
    rng = np.random.default_rng(5)
    batch = make_batch(rng)
    out = run(model.train_step, batch)
    g_center = np.asarray(out[1])
    assert np.abs(g_center).max() > 1e-6
