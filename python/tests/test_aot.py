"""AOT path: lowering to HLO text produces loadable, well-formed modules
(the tiny shape only — the 100M shape is exported by `make artifacts`)."""

import os

import numpy as np

from compile import aot


def test_to_hlo_text_well_formed(tmp_path):
    fname = aot.export_train_step(str(tmp_path), "tiny", aot.SHAPES["tiny"])
    text = (tmp_path / fname).read_text()
    assert "ENTRY" in text, "HLO text must contain an ENTRY computation"
    assert "f32[" in text
    # the tuple return carries 8 leaves
    assert text.count("ROOT") >= 1


def test_murmur_export_well_formed(tmp_path):
    fname = aot.export_murmur(str(tmp_path), 2, 16_384)
    text = (tmp_path / fname).read_text()
    assert "ENTRY" in text
    assert "u32[" in text


def test_manifest_written(tmp_path, monkeypatch):
    import sys

    monkeypatch.setattr(
        sys, "argv", ["aot", "--out", str(tmp_path), "--shapes", "tiny"]
    )
    assert aot.main() == 0
    manifest = (tmp_path / "MANIFEST.txt").read_text()
    assert "tiny" in manifest
    assert os.path.exists(tmp_path / "murmur_s4_n65536.hlo.txt")


def test_exported_hlo_numerics_roundtrip(tmp_path):
    """Execute the lowered tiny train step via jax from its stablehlo and
    compare against direct invocation — guards the export path itself."""
    import jax
    import jax.numpy as jnp

    from compile import model

    b, k, d, h = aot.SHAPES["tiny"]
    rng = np.random.default_rng(0)
    args = (
        rng.standard_normal((b, d)).astype(np.float32),
        rng.standard_normal((b, d)).astype(np.float32),
        rng.standard_normal((b, k, d)).astype(np.float32),
        (rng.standard_normal((d, h)) / np.sqrt(d)).astype(np.float32),
        np.zeros(h, np.float32),
        (rng.standard_normal((h, d)) / np.sqrt(h)).astype(np.float32),
        np.zeros(d, np.float32),
    )
    direct = model.train_step(*args)
    compiled = jax.jit(model.train_step).lower(*[jnp.asarray(a) for a in args]).compile()
    via_lowered = compiled(*args)
    for a, b_ in zip(direct, via_lowered):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-5, atol=1e-6)
