"""L1 kernel correctness: Pallas vs pure-jnp/numpy oracles.

The murmur vectors here are shared with rust
(rust/src/hashing/murmur.rs::murmur3_known_vectors) — both sides must
agree bit-for-bit or worker/server partition assignments diverge.
"""

import numpy as np
import pytest

from compile.kernels import hash as hash_kernel
from compile.kernels import matmul as matmul_kernel
from compile.kernels import ref

# ---------------------------------------------------------------------------
# MurmurHash: shared vectors + oracle equivalence
# ---------------------------------------------------------------------------

RUST_VECTORS = [
    # (key, seed, murmur3_32) — asserted identically in rust unit tests
    (0, 0, 0x2362F9DE),
    (1, 0, 0xFBF1402A),
    (0x12345678, 0x9747B28C, 0x461A9426),
    (42, 7, 0xDAEFE436),
]


def test_ref_matches_rust_vectors():
    for key, seed, expect in RUST_VECTORS:
        got = int(np.asarray(ref.murmur3_32_ref(np.array([key]), seed))[0])
        assert got == expect, f"murmur({key}, {seed}) = {got:#x} != {expect:#x}"


def test_pallas_matches_rust_vectors():
    keys = np.array([k for k, _, _ in RUST_VECTORS], dtype=np.uint32)
    for i, (_, seed, expect) in enumerate(RUST_VECTORS):
        out = np.asarray(hash_kernel.murmur_family(keys, np.array([seed])))
        assert int(out[0, i]) == expect


@pytest.mark.parametrize("n", [1, 7, 255, 4096, 16_384, 16_385, 50_000])
@pytest.mark.parametrize("n_seeds", [1, 4])
def test_pallas_matches_ref_shapes(n, n_seeds):
    """Hypothesis-style sweep over sizes incl. block boundaries."""
    rng = np.random.default_rng(n * 31 + n_seeds)
    keys = rng.integers(0, 2**32, size=n, dtype=np.uint32)
    seeds = rng.integers(0, 2**32, size=n_seeds, dtype=np.uint32)
    got = np.asarray(hash_kernel.murmur_family(keys, seeds))
    want = np.asarray(ref.murmur_family_ref(keys, seeds))
    np.testing.assert_array_equal(got, want)


def test_pallas_small_block():
    """Non-default block size exercises the grid path."""
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**32, size=1000, dtype=np.uint32)
    seeds = np.array([1, 2], dtype=np.uint32)
    got = np.asarray(hash_kernel.murmur_family(keys, seeds, block=128))
    want = np.asarray(ref.murmur_family_ref(keys, seeds))
    np.testing.assert_array_equal(got, want)


def test_empty_input():
    out = np.asarray(hash_kernel.murmur_family(np.array([], np.uint32), np.array([5], np.uint32)))
    assert out.shape == (1, 0)


# ---------------------------------------------------------------------------
# Hierarchical partition (scatter-min rounds)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(5))
def test_partition_lossless_and_consistent(seed):
    rng = np.random.default_rng(seed)
    n_idx = int(rng.integers(10, 3000))
    universe = int(rng.integers(n_idx, 200_000))
    indices = rng.choice(universe, size=n_idx, replace=False).astype(np.uint32)
    n_parts = int(rng.integers(1, 12))
    k = int(rng.integers(1, 5))
    r1 = int(rng.integers(8, 4 * n_idx // max(n_parts, 1) + 16))
    seeds = rng.integers(0, 2**32, size=k + 1, dtype=np.uint32)

    parts, mem, serial = hash_kernel.hierarchical_partition(
        indices, n_parts, k, r1, seeds
    )
    got = hash_kernel.extract_partitions(mem, serial, n_parts)

    # 1. Lossless: union of partitions == input set.
    all_got = np.sort(np.concatenate(got))
    np.testing.assert_array_equal(all_got, np.sort(indices))

    # 2. Partition assignment matches h0 exactly (== ref assignment).
    ref_parts, ref_lists = ref.hierarchical_partition_ref(
        indices, n_parts, k, r1, seeds
    )
    np.testing.assert_array_equal(np.asarray(parts), ref_parts.astype(np.int32))

    # 3. Per-partition contents match the reference partitioner's
    #    (contents depend only on h0; probing order does not move indices
    #    across partitions).
    for p in range(n_parts):
        np.testing.assert_array_equal(got[p], np.array(ref_lists[p], np.uint32))


def test_partition_balance():
    """Theorem 2 in miniature: hashed partitions are near-uniform."""
    rng = np.random.default_rng(7)
    indices = rng.choice(1_000_000, size=80_000, replace=False).astype(np.uint32)
    n_parts = 16
    seeds = rng.integers(0, 2**32, size=4, dtype=np.uint32)
    parts, _, _ = hash_kernel.hierarchical_partition(indices, n_parts, 3, 16_384, seeds)
    counts = np.bincount(np.asarray(parts), minlength=n_parts)
    imbalance = counts.max() * n_parts / counts.sum()
    assert imbalance < 1.1, f"imbalance {imbalance}"


def test_serial_region_takes_overflow():
    """Tiny r1 forces serial writes, still lossless."""
    indices = np.arange(500, dtype=np.uint32) * 7 + 3
    seeds = np.array([11, 22, 33], dtype=np.uint32)
    _, mem, serial = hash_kernel.hierarchical_partition(indices, 2, 2, 8, seeds)
    got = hash_kernel.extract_partitions(mem, serial, 2)
    assert sum(len(s) for s in serial) > 0, "expected serial-memory traffic"
    np.testing.assert_array_equal(np.sort(np.concatenate(got)), np.sort(indices))


# ---------------------------------------------------------------------------
# Pallas matmul kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,n",
    [(1, 1, 1), (4, 8, 16), (64, 32, 64), (128, 512, 512), (130, 33, 65), (256, 512, 512)],
)
def test_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 7 + k * 3 + n)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(matmul_kernel.matmul(x, w))
    want = np.asarray(ref.matmul_ref(x, w))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_matmul_grad_matches_jnp():
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(5)
    x = rng.standard_normal((32, 16)).astype(np.float32)
    w = rng.standard_normal((16, 8)).astype(np.float32)

    def f_pallas(x, w):
        return jnp.sum(jnp.tanh(matmul_kernel.matmul(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.tanh(jnp.matmul(x, w)))

    gx_p, gw_p = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw_p), np.asarray(gw_r), rtol=1e-4, atol=1e-5)
