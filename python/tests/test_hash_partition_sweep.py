"""Hypothesis-style randomized sweeps over the hierarchical partitioner:
shapes, dtypes edge cases, and parity of the fastrange reduction with
the rust implementation's shared vectors."""

import numpy as np
import pytest

from compile.kernels import hash as hash_kernel
from compile.kernels import ref


def test_reduce_matches_rust_semantics():
    # (h * n) >> 32 with known values — same arithmetic as
    # zen::hashing::murmur::HashFamily::reduce.
    h = np.array([0, 1, 0x80000000, 0xFFFFFFFF], dtype=np.uint32)
    out = np.asarray(hash_kernel._reduce(h, 16))
    assert list(out) == [0, 0, 8, 15]
    out7 = np.asarray(hash_kernel._reduce(h, 7))
    assert list(out7) == [0, 0, 3, 6]


@pytest.mark.parametrize("seed", range(8))
def test_partition_sweep_lossless(seed):
    rng = np.random.default_rng(1000 + seed)
    n_idx = int(rng.integers(1, 5000))
    universe = int(rng.integers(n_idx, 1_000_000))
    indices = rng.choice(universe, size=n_idx, replace=False).astype(np.uint32)
    n_parts = int(rng.integers(1, 17))
    k = int(rng.integers(1, 5))
    r1 = int(rng.integers(4, max(8, 3 * n_idx // max(n_parts, 1) + 8)))
    seeds = rng.integers(0, 2**32, size=k + 1, dtype=np.uint32)
    parts, mem, serial = hash_kernel.hierarchical_partition(
        indices, n_parts, k, r1, seeds
    )
    got = hash_kernel.extract_partitions(mem, serial, n_parts)
    np.testing.assert_array_equal(
        np.sort(np.concatenate(got)), np.sort(indices)
    )
    # partition ids in range and consistent with the reference
    ref_parts, _ = ref.hierarchical_partition_ref(indices, n_parts, k, r1, seeds)
    np.testing.assert_array_equal(np.asarray(parts), ref_parts.astype(np.int32))


def test_single_partition_degenerate():
    indices = np.arange(100, dtype=np.uint32)
    seeds = np.array([3, 5], dtype=np.uint32)
    parts, mem, serial = hash_kernel.hierarchical_partition(indices, 1, 1, 256, seeds)
    assert set(np.asarray(parts)) == {0}
    got = hash_kernel.extract_partitions(mem, serial, 1)
    np.testing.assert_array_equal(got[0], indices)


def test_max_index_value():
    # u32::MAX - 1 index must survive (sentinel is u32::MAX)
    indices = np.array([0, 1, 2**32 - 2], dtype=np.uint32)
    seeds = np.array([7, 9], dtype=np.uint32)
    _, mem, serial = hash_kernel.hierarchical_partition(indices, 2, 1, 16, seeds)
    got = hash_kernel.extract_partitions(mem, serial, 2)
    np.testing.assert_array_equal(
        np.sort(np.concatenate(got)), np.sort(indices)
    )
