"""AOT export: lower the L2/L1 graphs to HLO **text** artifacts.

HLO text (not `.serialize()`d protos) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Exports (under artifacts/):
  train_step_b{B}_k{K}_d{D}_h{H}.hlo.txt   — one per model shape
  murmur_s{S}_n{N}.hlo.txt                 — the L1 hash kernel alone
  MANIFEST.txt                             — shapes + input orders

Run via `make artifacts` (no-op when inputs are unchanged). Python never
runs on the training path: the rust binary loads these files.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.hash import murmur_family
from .model import train_step

#: (batch, negatives, dim, hidden) shapes to export. `tiny` drives tests
#: and CI; `paper_100m` drives the end-to-end 100M-parameter run.
SHAPES = {
    "tiny": (64, 4, 32, 64),
    "paper_100m": (256, 8, 512, 512),
}

#: Hash-kernel export: (num_seeds, num_indices).
HASH_EXPORTS = [(4, 65_536)]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_train_step(out_dir: str, name: str, shape) -> str:
    b, k, d, h = shape
    f32 = lambda *dims: jax.ShapeDtypeStruct(dims, jnp.float32)  # noqa: E731
    lowered = jax.jit(train_step).lower(
        f32(b, d),  # center
        f32(b, d),  # context
        f32(b, k, d),  # neg
        f32(d, h),  # w1
        f32(h),  # b1
        f32(h, d),  # w2
        f32(d),  # b2
    )
    text = to_hlo_text(lowered)
    fname = f"train_step_b{b}_k{k}_d{d}_h{h}.hlo.txt"
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    print(f"  [{name}] {fname}: {len(text)} chars")
    return fname


def export_murmur(out_dir: str, n_seeds: int, n_idx: int) -> str:
    u32 = lambda *dims: jax.ShapeDtypeStruct(dims, jnp.uint32)  # noqa: E731
    lowered = jax.jit(lambda idx, seeds: (murmur_family(idx, seeds),)).lower(
        u32(n_idx), u32(n_seeds)
    )
    text = to_hlo_text(lowered)
    fname = f"murmur_s{n_seeds}_n{n_idx}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    print(f"  [hash] {fname}: {len(text)} chars")
    return fname


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--shapes",
        default="all",
        help="comma-separated shape names (tiny,paper_100m) or 'all'",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = list(SHAPES) if args.shapes == "all" else args.shapes.split(",")
    manifest = [
        "# zen-sync AOT artifacts",
        "# train_step inputs: center(B,D) context(B,D) neg(B,K,D) "
        "w1(D,H) b1(H) w2(H,D) b2(D)",
        "# train_step outputs: loss, g_center, g_context, g_neg, "
        "g_w1, g_b1, g_w2, g_b2",
    ]
    for name in names:
        shape = SHAPES[name]
        fname = export_train_step(args.out, name, shape)
        manifest.append(f"{name}: {fname} shape={shape}")
    for s, n in HASH_EXPORTS:
        fname = export_murmur(args.out, s, n)
        manifest.append(f"murmur: {fname} seeds={s} n={n}")
    with open(os.path.join(args.out, "MANIFEST.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {args.out}/MANIFEST.txt")
    return 0


if __name__ == "__main__":
    sys.exit(main())
