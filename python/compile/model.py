"""L2: the embedding-LM compute graph (build-time JAX, never at runtime).

Skip-gram with negative sampling plus an MLP projection head — the
Table-1 model class (huge sparse embedding table + small dense head).
The rust coordinator gathers the embedding rows touched by the batch and
passes *only those rows* here, so this graph is vocabulary-size-free and
one exported artifact serves any table size; the embedding gradient that
flows back out is exactly the sparse tensor the paper synchronizes.

    hid    = tanh(center @ W1 + b1)          # Pallas matmul kernel
    proj   = hid @ W2 + b2                   # Pallas matmul kernel
    loss   = mean softplus(-proj·context) + mean Σ_k softplus(proj·neg_k)

`train_step` returns (loss, grads...) — lowered once by aot.py.
"""

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul


def forward_loss(center, context, neg, w1, b1, w2, b2):
    """Scalar SGNS loss for a batch of gathered rows.

    Shapes: center/context (B, D), neg (B, K, D),
            w1 (D, H), b1 (H,), w2 (H, D), b2 (D,).
    """
    hid = jnp.tanh(matmul(center, w1) + b1)
    proj = matmul(hid, w2) + b2
    pos_logit = jnp.sum(proj * context, axis=-1)  # (B,)
    neg_logit = jnp.einsum("bd,bkd->bk", proj, neg)  # (B, K)
    softplus = lambda x: jnp.logaddexp(0.0, x)  # noqa: E731
    loss_pos = jnp.mean(softplus(-pos_logit))
    loss_neg = jnp.mean(jnp.sum(softplus(neg_logit), axis=-1))
    return loss_pos + loss_neg


def train_step(center, context, neg, w1, b1, w2, b2):
    """Loss + gradients w.r.t. every input (rows and MLP parameters).

    Returned tuple order is the rust-side contract
    (rust/src/coordinator/lm.rs):
      (loss, g_center, g_context, g_neg, g_w1, g_b1, g_w2, g_b2)
    """
    loss, grads = jax.value_and_grad(forward_loss, argnums=(0, 1, 2, 3, 4, 5, 6))(
        center, context, neg, w1, b1, w2, b2
    )
    return (loss, *grads)


def forward_loss_ref(center, context, neg, w1, b1, w2, b2):
    """Oracle without the Pallas kernel (pure jnp) for pytest."""
    hid = jnp.tanh(jnp.matmul(center, w1) + b1)
    proj = jnp.matmul(hid, w2) + b2
    pos_logit = jnp.sum(proj * context, axis=-1)
    neg_logit = jnp.einsum("bd,bkd->bk", proj, neg)
    softplus = lambda x: jnp.logaddexp(0.0, x)  # noqa: E731
    return jnp.mean(softplus(-pos_logit)) + jnp.mean(
        jnp.sum(softplus(neg_logit), axis=-1)
    )


def train_step_ref(center, context, neg, w1, b1, w2, b2):
    """Oracle train step (pure jnp) for pytest."""
    loss, grads = jax.value_and_grad(
        forward_loss_ref, argnums=(0, 1, 2, 3, 4, 5, 6)
    )(center, context, neg, w1, b1, w2, b2)
    return (loss, *grads)
