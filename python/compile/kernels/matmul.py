"""L1 Pallas kernel: tiled matmul with a custom VJP.

The MLP head of the L2 model runs its three matmul instances (forward,
dX, dW) through this kernel so the whole train step lowers with the
Pallas path inside. Tiling: grid over M-blocks with full K and N per
tile — MXU-shaped (the K×N operand stays resident in VMEM across the M
sweep; for the export shapes `128×512×4B ≈ 1 MB` per operand tile).

`interpret=True` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; on a real TPU the same BlockSpecs compile unchanged.

pallas_call has no automatic autodiff, so `matmul` carries a
`jax.custom_vjp` whose backward pass reuses the same kernel
(dx = g @ wᵀ, dw = xᵀ @ g).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: M-dimension tile.
BLOCK_M = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (BLOCK_M, K) × (K, N) tile product on the MXU."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_m",))
def _matmul_pallas(x, w, block_m=BLOCK_M):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = min(block_m, m)
    padded_m = ((m + bm - 1) // bm) * bm
    x_p = jnp.zeros((padded_m, k), x.dtype).at[:m].set(x)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(padded_m // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_m, n), jnp.float32),
        interpret=True,
    )(x_p, w)
    return out[:m]


@jax.custom_vjp
def matmul(x, w):
    """`x @ w` through the Pallas kernel, differentiable."""
    return _matmul_pallas(x, w)


def _matmul_fwd(x, w):
    return _matmul_pallas(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    dx = _matmul_pallas(g, w.T)
    dw = _matmul_pallas(x.T, g)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)
