"""L1 Pallas kernel: the hashing hot-spot of Algorithm 1.

The paper implements per-index MurmurHash + collision probing as a CUDA
kernel (one thread per index, atomic writes). §Hardware-Adaptation
(DESIGN.md): on TPU there are no per-element atomics, so we split the
algorithm into

  1. `murmur_family` — a **Pallas kernel** computing all k+1 hash values
     for a block of indices, fully vectorized on the VPU. BlockSpec
     tiles the index vector so each tile (block × (k+1) u32 lanes) fits
     VMEM.
  2. `hierarchical_partition` — k rounds of deterministic **scatter-min**
     in jnp around the kernel: round i writes `idx` into
     `mem[p, h_i(idx)]` with min-combining; an index that reads back its
     own value won; losers proceed to the next round, and round-k losers
     are compacted via cumsum into the serial region. Deterministic,
     parallel, lossless — the same guarantees as the CUDA atomics.

Pallas runs with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); the kernel structure (BlockSpec tiling, vector ops only,
no gather/scatter inside the kernel) is what would compile for real TPU.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# numpy scalars (not jnp arrays: pallas kernels may not capture traced
# constants; np.uint32 combines with uint32 arrays without promotion).
_C1 = np.uint32(0xCC9E2D51)
_C2 = np.uint32(0x1B873593)
_M5 = np.uint32(5)
_MF = np.uint32(0xE6546B64)
_F1 = np.uint32(0x85EBCA6B)
_F2 = np.uint32(0xC2B2AE35)

#: Index block per kernel invocation. 16K u32 indices × (k+1) hash rows
#: ≈ 16K·4B·(1+k+1) ≤ 400 KB VMEM at k = 4 — comfortably inside a
#: TensorCore's ~16 MB VMEM with double-buffering headroom.
BLOCK = 16_384


def _rotl(x, r):
    return ((x << r) | (x >> (32 - r))).astype(jnp.uint32)


def _reduce(h, n):
    """Lemire multiply-shift range reduction `(h * n) >> 32` — matches
    rust `HashFamily::reduce` bit-for-bit (the perf pass replaced `%`).

    Runs on host numpy: jax without x64 would silently truncate the
    64-bit product, and this step is part of the (host-side) partition
    orchestration, not the exported Pallas kernel.
    """
    h64 = np.asarray(h).astype(np.uint64)
    return jnp.asarray(((h64 * np.uint64(n)) >> np.uint64(32)).astype(np.uint32))


def _murmur_kernel(idx_ref, seeds_ref, out_ref):
    """out[s, :] = murmur3_32(idx, seeds[s]) for every seed s.

    Pure VPU element-wise integer ops over a (BLOCK,) tile; seeds is a
    small replicated vector.
    """
    idx = idx_ref[...].astype(jnp.uint32)
    seeds = seeds_ref[...].astype(jnp.uint32)
    k = (idx * _C1).astype(jnp.uint32)
    k = _rotl(k, 15)
    k = (k * _C2).astype(jnp.uint32)
    # broadcast over seeds: (S, BLOCK)
    h = seeds[:, None] ^ k[None, :]
    h = _rotl(h, 13)
    h = (h * _M5 + _MF).astype(jnp.uint32)
    h = h ^ np.uint32(4)
    h = h ^ (h >> 16)
    h = (h * _F1).astype(jnp.uint32)
    h = h ^ (h >> 13)
    h = (h * _F2).astype(jnp.uint32)
    h = h ^ (h >> 16)
    out_ref[...] = h.astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block",))
def murmur_family(indices, seeds, block=BLOCK):
    """All seeds' murmur hashes of `indices`: shape (S, N).

    Pads N up to a multiple of `block`; the pad lanes are discarded.
    """
    indices = jnp.asarray(indices, dtype=jnp.uint32)
    seeds = jnp.asarray(seeds, dtype=jnp.uint32)
    n = indices.shape[0]
    s = seeds.shape[0]
    padded = ((n + block - 1) // block) * block if n > 0 else block
    idx_p = jnp.zeros((padded,), jnp.uint32).at[:n].set(indices)
    grid = padded // block
    out = pl.pallas_call(
        _murmur_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((s,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((s, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((s, padded), jnp.uint32),
        interpret=True,
    )(idx_p, seeds)
    return out[:, :n]


def hierarchical_partition(indices, n_parts, n_rounds, r1, seeds):
    """Algorithm 1 with scatter-min collision resolution (see module doc).

    Args:
      indices: uint32[N] distinct non-zero-gradient indices.
      n_parts: number of partitions (servers) n.
      n_rounds: probe rounds k.
      r1: parallel memory slots per partition.
      seeds: uint32[k+1] hash seeds (h0 first).

    Returns:
      parts: int32[N] partition of every index (== h0 % n).
      placed_memory: uint32[n_parts, r1] parallel memory (SENTINEL=empty).
      serial: list of n_parts uint32 arrays — the round-k losers per
        partition (the serial memory content).
    """
    sentinel = jnp.uint32(0xFFFFFFFF)
    idx = jnp.asarray(indices, dtype=jnp.uint32)
    n = idx.shape[0]
    h = murmur_family(idx, seeds)  # (k+1, N)
    parts = _reduce(h[0], n_parts).astype(jnp.int32)

    mem = jnp.full((n_parts * r1,), sentinel, jnp.uint32)
    alive = jnp.ones((n,), bool)
    for rnd in range(1, n_rounds + 1):
        slot = _reduce(h[rnd], r1).astype(jnp.int32)
        addr = parts * r1 + slot
        # Deterministic winner per slot: scatter-min of the index value
        # into a per-round scratch, adopted only by still-empty slots
        # (occupied slots from earlier rounds must never be overwritten).
        cand = jnp.where(alive, idx, sentinel)
        scratch = jnp.full_like(mem, sentinel).at[addr].min(cand)
        mem = jnp.where(mem == sentinel, scratch, mem)
        won = alive & (mem[addr] == idx)
        alive = alive & ~won
    serial_mask = np.asarray(alive)
    parts_np = np.asarray(parts)
    idx_np = np.asarray(idx)
    serial = [
        np.sort(idx_np[serial_mask & (parts_np == p)]).astype(np.uint32)
        for p in range(n_parts)
    ]
    return parts, mem.reshape(n_parts, r1), serial


def extract_partitions(mem, serial, n_parts):
    """Extraction phase (Alg 1 lines 19–23): collect each partition's
    indices from parallel + serial memory, sorted."""
    sentinel = np.uint32(0xFFFFFFFF)
    mem = np.asarray(mem)
    out = []
    for p in range(n_parts):
        row = mem[p]
        occupied = row[row != sentinel]
        merged = np.concatenate([occupied, serial[p]])
        out.append(np.sort(merged).astype(np.uint32))
    return out
