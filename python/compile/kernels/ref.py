"""Pure-jnp / numpy oracles for the Pallas kernels.

Everything in this file is the *correctness ground truth*: the Pallas
kernels in hash.py / matmul.py must match these bit-for-bit (integers)
or to float tolerance (matmuls). The murmur reference also matches the
rust implementation in rust/src/hashing/murmur.rs — shared test vectors
are asserted in python/tests/test_kernel.py.
"""

import jax.numpy as jnp
import numpy as np

_C1 = np.uint32(0xCC9E2D51)
_C2 = np.uint32(0x1B873593)
_M5 = np.uint32(5)
_MF = np.uint32(0xE6546B64)
_F1 = np.uint32(0x85EBCA6B)
_F2 = np.uint32(0xC2B2AE35)


def _rotl32(x, r):
    """Rotate-left for uint32 arrays."""
    x = x.astype(jnp.uint32)
    return ((x << r) | (x >> (32 - r))).astype(jnp.uint32)


def murmur3_32_ref(keys, seed):
    """MurmurHash3 x86_32 over a uint32 key array with a scalar seed.

    Matches rust `zen::hashing::murmur::murmur3_32` exactly.
    """
    k = jnp.asarray(keys, dtype=jnp.uint32)
    seed = jnp.uint32(seed)
    k = (k * _C1).astype(jnp.uint32)
    k = _rotl32(k, 15)
    k = (k * _C2).astype(jnp.uint32)
    h = seed ^ k
    h = _rotl32(h, 13)
    h = (h * _M5 + _MF).astype(jnp.uint32)
    h = h ^ jnp.uint32(4)  # key length = 4 bytes
    h = h ^ (h >> 16)
    h = (h * _F1).astype(jnp.uint32)
    h = h ^ (h >> 13)
    h = (h * _F2).astype(jnp.uint32)
    h = h ^ (h >> 16)
    return h


def murmur_family_ref(keys, seeds):
    """Stack of murmur hashes, one row per seed: shape (len(seeds), N)."""
    return jnp.stack([murmur3_32_ref(keys, s) for s in np.asarray(seeds)], axis=0)


def matmul_ref(x, w):
    """Plain jnp matmul oracle."""
    return jnp.matmul(x, w)


def hierarchical_partition_ref(indices, n_parts, n_rounds, r1, seeds):
    """Numpy reference of Algorithm 1's partition assignment + probing.

    Sequential and obviously correct: for each index in order, try the k
    probe slots; on total collision append to the serial list.
    Losslessness holds by construction. The Pallas/jnp version (hash.py)
    replaces sequential probing with deterministic scatter-min rounds, so
    slot *winners* can differ — tests compare the partition assignment
    (depends only on h0, must match exactly) and losslessness.
    """
    idx = np.asarray(indices, dtype=np.uint32)
    h = np.asarray(murmur_family_ref(idx, seeds))
    # Lemire multiply-shift reduction, matching rust HashFamily::reduce.
    parts = ((h[0].astype(np.uint64) * np.uint64(n_parts)) >> np.uint64(32)).astype(np.uint32)
    out = [[] for _ in range(n_parts)]
    mem = {}
    serial = [[] for _ in range(n_parts)]
    for i, v in enumerate(idx):
        p = int(parts[i])
        placed = False
        for r in range(1, n_rounds + 1):
            slot = int((int(h[r, i]) * r1) >> 32)
            key = (p, slot)
            if key not in mem:
                mem[key] = v
                placed = True
                break
        if not placed:
            serial[p].append(int(v))
    for (p, _), v in mem.items():
        out[p].append(int(v))
    for p in range(n_parts):
        out[p].extend(serial[p])
        out[p].sort()
    return parts, out
